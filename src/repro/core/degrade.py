"""Degraded-mode fabric for the mesh engines: integrity + failover policy.

The paper's central claim is that a partitioned sampler tolerates *stale*
boundary information — convergence persists with a quantifiably reduced
power-law exponent, governed by eta = f_comm/f_pbit.  This module turns
that physics into the machine's failure-handling contract:

* every boundary exchange carries a **wire header** ``[seq, checksum]``
  (uint32 each) alongside the payload, so a corrupted, dropped, or
  out-of-order exchange is *detected* by the receiver instead of ingested;
* a :class:`DegradePolicy` says what happens next — ``fail_fast`` raises
  :class:`StateCorruption` at the first detection, ``stale_hold`` keeps
  sweeping on last-known-good ghosts until a per-source staleness budget
  is exhausted, ``freeze_boundary`` pins the boundary permanently after
  the first detection and never escalates;
* a :class:`MeshHealthMonitor` keeps the host-side view: cumulative
  detection/held counters, per-source staleness, quarantine (``suspect``)
  marking, and the ``resync()`` bookkeeping when an engine forces an
  instantaneous full-boundary refresh.

The in-trace side lives in the engines (``core/dsim_dist.py`` /
``core/lattice_dsim.py``): the health carry is a 6-tuple of replicated
scalars/vectors threaded through the chunk scan, and held exchanges are
``jnp.where`` selections against the carried (last-known-good) ghosts, so
a run with zero detections is bitwise identical to an unchecked run.

Wire checksum: a position-weighted modular sum over the payload viewed as
uint32 words — ``sum(w_i * (i * 2654435761 + 1)) mod 2^32``.  The odd
per-position weights make it order-sensitive (a swapped pair of words is
detected, unlike a plain sum) while staying one multiply-add per word.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple, Union

import numpy as np

__all__ = ["StateCorruption", "DegradePolicy", "MeshHealthMonitor",
           "health_init", "wire_checksum", "wire_words", "DEGRADE_MODES"]

DEGRADE_MODES = ("fail_fast", "stale_hold", "freeze_boundary")

# one odd multiplier per word position (Knuth's 2^32/phi); position-
# sensitive so reordered payload words fail the check
_CK_MULT = 2654435761


class StateCorruption(RuntimeError):
    """Engine state failed an integrity check.

    Raised by the serving integrity guard (non-finite recorded energies)
    and by the degraded-mode mesh when a :class:`DegradePolicy` escalates:
    ``fail_fast`` at the first detected-bad exchange, ``stale_hold`` when
    a boundary source exceeds its staleness budget.  Classified transient
    by ``serve.faults.classify_error`` — a retry from the last checkpoint
    re-runs the trajectory with fresh state.
    """


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """What a mesh engine does when a boundary exchange fails integrity.

    mode:
      * ``"fail_fast"``      — raise :class:`StateCorruption` at the first
                               detection (the pre-degraded-mode behavior,
                               made explicit and immediate).
      * ``"stale_hold"``     — hold last-known-good ghosts for the bad
                               source(s), keep sweeping, escalate once any
                               source's consecutive-held count exceeds
                               ``max_staleness`` exchanges.
      * ``"freeze_boundary"``— after the first detection, pin ALL boundary
                               ghosts permanently (the mesh decouples into
                               independent bricks); never escalates.

    ``max_staleness`` is counted in *exchanges* (one per ``sync_every``
    sweeps), per source — partition k for ``dsim_dist``, face index for
    the lattice engine.
    """

    mode: str = "stale_hold"
    max_staleness: int = 8

    MODES: ClassVar[Tuple[str, ...]] = DEGRADE_MODES

    def __post_init__(self):
        if self.mode not in DEGRADE_MODES:
            raise ValueError(f"unknown degrade mode {self.mode!r}; "
                             f"expected one of {DEGRADE_MODES}")
        if int(self.max_staleness) < 0:
            raise ValueError("max_staleness must be >= 0")

    @classmethod
    def parse(cls, spec: Union[None, str, "DegradePolicy"]) \
            -> Optional["DegradePolicy"]:
        """None | DegradePolicy | "fail_fast" | "stale_hold[:N]" |
        "freeze_boundary" -> DegradePolicy (or None)."""
        if spec is None or isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            name, _, arg = spec.partition(":")
            if arg and name != "stale_hold":
                raise ValueError(
                    f"degrade policy {spec!r}: only stale_hold takes a "
                    "staleness budget")
            if name == "stale_hold" and arg:
                return cls(name, int(arg))
            return cls(name)
        raise TypeError(f"cannot parse degrade policy from {type(spec)}")

    def key(self) -> str:
        """Canonical string form (hashable, round-trips through parse)."""
        if self.mode == "stale_hold":
            return f"stale_hold:{int(self.max_staleness)}"
        return self.mode


def health_init(n_sources: int) -> tuple:
    """Fresh health carry: (seq, stale[n_sources], frozen, detections,
    held, max_staleness) — uint32 exchange counter, per-source consecutive-
    held counts, sticky freeze flag, and cumulative event counters.  Plain
    numpy scalars/arrays; jit converts at the boundary."""
    return (np.uint32(0), np.zeros(int(n_sources), np.int32), np.int32(0),
            np.int32(0), np.int32(0), np.int32(0))


def wire_words(x):
    """Reinterpret an exchange payload as uint32 words for checksumming.

    int8 planes widen via a uint8 bitcast (sign-safe), f32 pools bitcast
    directly, native word planes pass through — so sender and receiver
    checksum the exact same bit pattern regardless of which representation
    each side holds.
    """
    import jax
    import jax.numpy as jnp
    if x.dtype == jnp.uint32:
        return x
    if x.dtype == jnp.int8:
        return jax.lax.bitcast_convert_type(x, jnp.uint8).astype(jnp.uint32)
    if x.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    return x.astype(jnp.uint32)


def wire_checksum(x) -> "jnp.ndarray":
    """Position-weighted modular checksum of a payload (scalar uint32)."""
    import jax.numpy as jnp
    w = wire_words(x).reshape(-1)
    mult = (jnp.arange(w.shape[0], dtype=jnp.uint32) * jnp.uint32(_CK_MULT)
            + jnp.uint32(1))
    return (w * mult).sum(dtype=jnp.uint32)


class MeshHealthMonitor:
    """Host-side keeper of a mesh engine's exchange-health carry.

    The engine threads the carry (see :func:`health_init`) through its
    jitted chunk; after every chunk it hands the updated carry back via
    :meth:`update`, which pulls the counters to the host, feeds the
    cumulative totals, and enforces the policy (raising
    :class:`StateCorruption` when it escalates).  ``resync()`` on the
    engine calls :meth:`on_resync` to clear staleness/quarantine after an
    instantaneous full-boundary refresh.

    Counter semantics (all cumulative over the current run):

    * ``detections``        — exchanges where >= 1 source failed the wire
                              check (the integrity counter).
    * ``stale_exchanges``   — exchanges where >= 1 source was *held* at
                              last-known-good (== detections under
                              stale_hold/fail_fast; larger under
                              freeze_boundary, which holds forever).
    * ``max_staleness_seen``— worst consecutive-held count of any source.
    * ``exchanges_total``   — exchanges attempted (host-side: the chunk
                              iteration count, fed by the engine).
    """

    def __init__(self, policy: DegradePolicy, n_sources: int,
                 kind: str = "partitions"):
        self.policy = policy
        self.n_sources = int(n_sources)
        self.kind = kind
        self.resyncs = 0
        self.reset()

    def reset(self):
        """Fresh carry + counters (called at the start of every run)."""
        self.carry = health_init(self.n_sources)
        self.exchanges_total = 0
        self.detections = 0
        self.stale_exchanges = 0
        self.max_staleness_seen = 0

    @property
    def suspect(self) -> bool:
        """Quarantine mark: any source has failed integrity and no resync
        has cleared the staleness since."""
        return bool(np.asarray(self.carry[1]).max(initial=0) > 0
                    or int(self.carry[2]) > 0)

    @property
    def staleness(self) -> np.ndarray:
        """Per-source consecutive-held exchange counts (copy)."""
        return np.asarray(self.carry[1]).copy()

    @property
    def delivered_fraction(self) -> float:
        """Fraction of exchanges fully ingested — the effective-eta factor
        (eta scales with delivered boundary-refresh frequency)."""
        if not self.exchanges_total:
            return 1.0
        return max(0.0, 1.0 - self.stale_exchanges / self.exchanges_total)

    def update(self, carry, exchanges: int):
        """Absorb a post-chunk carry, then enforce the policy.

        Host-syncs the carry (one small device->host pull per chunk — the
        documented cost of enabling a degrade policy; disabled engines pay
        nothing).  Raises :class:`StateCorruption` per the policy.
        """
        self.carry = carry
        _, _, _, det, held, maxst = (np.asarray(x) for x in carry)
        self.exchanges_total += int(exchanges)
        self.detections = int(det)
        self.stale_exchanges = int(held)
        self.max_staleness_seen = max(self.max_staleness_seen, int(maxst))
        p = self.policy
        if p.mode == "fail_fast" and self.detections:
            raise StateCorruption(
                f"boundary integrity failure: {self.detections} bad "
                f"exchange(s) detected on the {self.kind} wire "
                "(policy fail_fast)")
        if p.mode == "stale_hold" \
                and self.max_staleness_seen > p.max_staleness:
            raise StateCorruption(
                f"boundary staleness {self.max_staleness_seen} exceeded "
                f"budget {p.max_staleness} exchanges (policy stale_hold; "
                "resync() or retry required)")

    def on_resync(self):
        """Clear staleness + freeze after a full-boundary refresh; the
        cumulative detection counters are history and stay."""
        seq, stale, _, det, held, maxst = self.carry
        self.carry = (seq, np.zeros(self.n_sources, np.int32), np.int32(0),
                      det, held, maxst)
        self.resyncs += 1

    def report(self) -> dict:
        """Provenance dict (JSON-safe) for job results and dashboards."""
        return {
            "policy": self.policy.key(),
            "detections": self.detections,
            "stale_exchanges": self.stale_exchanges,
            "exchanges_total": self.exchanges_total,
            "max_staleness_seen": self.max_staleness_seen,
            "delivered_fraction": self.delivered_fraction,
            "resyncs": self.resyncs,
            "suspect": self.suspect,
            "sources": self.kind,
            "staleness": [int(v) for v in np.asarray(self.carry[1])],
        }
