"""Communication-cost metric and the conservative clocking bound (paper S4).

Given a partition and a physical interconnect topology:

  b_ab : boundary p-bits cluster a must ship to cluster b
  d_ab : hop distance between the devices hosting a and b
  P_ab : data pins of the narrowest link on the a->b route

  C_tot = sum_{a<b} b_ab * d_ab / P_ab          (Eq. S.2)
  C_max = max_{a<b} b_ab * d_ab / P_ab          (Eq. S.3)
  f_p-bit <= f_comm / (2 * N_color * C_max)     (Eq. 2 / S.6)
  eta_threshold = 2 * N_color * C_max

On TPU the "pins" of a link are its per-hop byte budget per communication
clock; the same algebra applies (DESIGN.md, hardware adaptation).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import numpy as np

__all__ = ["boundary_matrix", "ChainTopology", "RingTopology", "CommCost",
           "comm_cost", "eta_threshold", "best_chain_permutation",
           "cut_distance_histogram"]


def boundary_matrix(idx: np.ndarray, w: np.ndarray, labels: np.ndarray,
                    K: int) -> np.ndarray:
    """b[a, b] = number of p-bits in cluster a with >=1 cut edge into b."""
    n, dmax = idx.shape
    src = np.repeat(np.arange(n), dmax)
    dst = idx.ravel()
    m = w.ravel() != 0
    la, lb = labels[src[m]], labels[dst[m]]
    cut = la != lb
    # boundary p-bit (node, dest-cluster) pairs, deduplicated
    pairs = np.unique(np.stack([src[m][cut], lb[cut]], axis=1), axis=0)
    b = np.zeros((K, K), dtype=np.int64)
    np.add.at(b, (labels[pairs[:, 0]], pairs[:, 1]), 1)
    return b


@dataclasses.dataclass(frozen=True)
class ChainTopology:
    """K devices in a chain; pins[i] = width of the link between slot i, i+1."""

    pins: Sequence[int]

    @property
    def k(self) -> int:
        return len(self.pins) + 1

    def hop(self, a: int, b: int) -> int:
        return abs(a - b)

    def bottleneck(self, a: int, b: int) -> int:
        lo, hi = min(a, b), max(a, b)
        return int(min(self.pins[lo:hi]))


@dataclasses.dataclass(frozen=True)
class RingTopology:
    """K devices on a bidirectional ring with uniform link width (TPU ICI-like)."""

    k: int
    pins_per_link: int

    def hop(self, a: int, b: int) -> int:
        d = abs(a - b)
        return min(d, self.k - d)

    def bottleneck(self, a: int, b: int) -> int:
        return self.pins_per_link


@dataclasses.dataclass(frozen=True)
class CommCost:
    c_tot: float
    c_max: float
    worst_pair: tuple
    per_pair: dict


def comm_cost(b: np.ndarray, topo, order: Optional[np.ndarray] = None) -> CommCost:
    """Cost of mapping clusters onto physical slots in the given order.

    ``order[a]`` = physical slot of cluster a (identity if None).
    Boundary traffic is duplex; we use b_ab + b_ba per unordered pair as the
    per-pair shipped states (each side needs the other's boundary bits).
    """
    K = b.shape[0]
    order = np.arange(K) if order is None else np.asarray(order)
    c_tot, c_max, worst = 0.0, 0.0, (0, 0)
    per_pair = {}
    for a in range(K):
        for bb in range(a + 1, K):
            states = int(b[a, bb] + b[bb, a])
            if states == 0:
                continue
            sa, sb = int(order[a]), int(order[bb])
            d = topo.hop(sa, sb)
            p = topo.bottleneck(sa, sb)
            c = states * d / p
            per_pair[(a, bb)] = c
            c_tot += c
            if c > c_max:
                c_max, worst = c, (a, bb)
    return CommCost(c_tot=c_tot, c_max=c_max, worst_pair=worst, per_pair=per_pair)


def eta_threshold(n_color: int, c_max: float) -> float:
    """Eq. 2: the ratio above which the distributed machine matches monolithic."""
    return 2.0 * n_color * c_max


def best_chain_permutation(b: np.ndarray, topo: ChainTopology,
                           objective: str = "c_tot"):
    """Search slot orderings (exhaustive K<=8, else greedy adjacent swaps)."""
    K = b.shape[0]

    def score(order):
        c = comm_cost(b, topo, order)
        return c.c_tot if objective == "c_tot" else c.c_max

    if K <= 8:
        best, best_s = None, np.inf
        for perm in itertools.permutations(range(K)):
            if perm[0] > perm[-1]:
                continue  # skip reversals
            s = score(np.asarray(perm))
            if s < best_s:
                best, best_s = np.asarray(perm), s
        return best, best_s
    order = np.arange(K)
    best_s = score(order)
    improved = True
    while improved:
        improved = False
        for i in range(K - 1):
            trial = order.copy()
            trial[i], trial[i + 1] = trial[i + 1], trial[i]
            s = score(trial)
            if s < best_s:
                order, best_s, improved = trial, s, True
    return order, best_s


def cut_distance_histogram(idx: np.ndarray, w: np.ndarray, labels: np.ndarray,
                           order: Optional[np.ndarray] = None,
                           K: Optional[int] = None) -> np.ndarray:
    """Fraction of cut edges at each hop distance on a chain (paper Fig. S5)."""
    n, dmax = idx.shape
    K = int(labels.max()) + 1 if K is None else K
    order = np.arange(K) if order is None else np.asarray(order)
    src = np.repeat(np.arange(n), dmax)
    dst = idx.ravel()
    m = (w.ravel() != 0) & (src < dst)
    la, lb = labels[src[m]], labels[dst[m]]
    cut = la != lb
    d = np.abs(order[la[cut]] - order[lb[cut]])
    hist = np.bincount(d, minlength=K)[1:]  # distances 1..K-1
    total = hist.sum()
    return hist / max(total, 1)
