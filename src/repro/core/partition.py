"""Balanced graph partitioning: lattice slabs/bricks + general greedy/refined.

The paper uses METIS (DSIM-2) and a topology-aware Potts objective (DSIM-1,
see :mod:`repro.core.potts_partition`).  METIS is not available offline; the
greedy multi-source BFS + boundary refinement below plays its role (balanced
min-cut-ish), and the Potts partitioner is implemented faithfully.
"""

from __future__ import annotations

import numpy as np

__all__ = ["slab_partition", "brick_partition", "greedy_partition",
           "refine_partition", "cut_edges", "partition_sizes"]


def slab_partition(L: int, K: int, axis: int = 0) -> np.ndarray:
    """Split an L^3 lattice into K contiguous slabs along one axis (chain map)."""
    xs, ys, zs = np.meshgrid(np.arange(L), np.arange(L), np.arange(L), indexing="ij")
    coord = (xs, ys, zs)[axis].ravel()
    return (coord * K // L).astype(np.int32)


def brick_partition(dims, bricks) -> np.ndarray:
    """Split an (Lx, Ly, Lz) lattice into a (kx, ky, kz) grid of bricks.

    Brick id is linearized in the same (x-major) order as the mesh axes, so a
    (pod, data, model) mesh maps onto (kx, ky, kz) bricks directly.
    """
    (Lx, Ly, Lz), (kx, ky, kz) = dims, bricks
    xs, ys, zs = np.meshgrid(np.arange(Lx), np.arange(Ly), np.arange(Lz),
                             indexing="ij")
    bx = xs.ravel() * kx // Lx
    by = ys.ravel() * ky // Ly
    bz = zs.ravel() * kz // Lz
    return ((bx * ky + by) * kz + bz).astype(np.int32)


def partition_sizes(labels: np.ndarray, K: int) -> np.ndarray:
    return np.bincount(labels, minlength=K)


def cut_edges(idx: np.ndarray, w: np.ndarray, labels: np.ndarray) -> int:
    """Number of undirected cut edges."""
    n, d = idx.shape
    src = np.repeat(np.arange(n), d)
    dst = idx.ravel()
    m = (w.ravel() != 0) & (src < dst)
    return int((labels[src[m]] != labels[dst[m]]).sum())


def greedy_partition(idx: np.ndarray, w: np.ndarray, K: int,
                     seed: int = 0) -> np.ndarray:
    """Balanced multi-source BFS growth (METIS stand-in)."""
    n, dmax = idx.shape
    rng = np.random.default_rng(seed)
    valid = w != 0
    labels = np.full(n, -1, dtype=np.int32)

    # spread seeds: start random, then greedily pick far nodes by BFS level
    seeds = [int(rng.integers(n))]
    dist = _bfs_dist(idx, valid, seeds[0])
    for _ in range(K - 1):
        cand = int(np.argmax(np.where(labels == -1, dist, -1)))
        seeds.append(cand)
        dist = np.minimum(dist, _bfs_dist(idx, valid, cand))
    frontiers = []
    for k, s in enumerate(seeds):
        labels[s] = k
        frontiers.append([s])

    sizes = np.ones(K, dtype=np.int64)
    target = n / K
    assigned = K
    while assigned < n:
        k = int(np.argmin(sizes))
        # expand the smallest partition by one BFS layer (or steal a random node)
        new_frontier = []
        grew = False
        for u in frontiers[k]:
            for t in range(dmax):
                if not valid[u, t]:
                    continue
                v = int(idx[u, t])
                if labels[v] == -1:
                    labels[v] = k
                    sizes[k] += 1
                    assigned += 1
                    new_frontier.append(v)
                    grew = True
                    if sizes[k] >= target + 1:
                        break
            if sizes[k] >= target + 1:
                break
        frontiers[k] = new_frontier + [u for u in frontiers[k] if _has_free(idx, valid, labels, u)]
        if not grew:
            free = np.nonzero(labels == -1)[0]
            v = int(free[rng.integers(len(free))])
            labels[v] = k
            sizes[k] += 1
            assigned += 1
            frontiers[k].append(v)
    return labels


def _has_free(idx, valid, labels, u) -> bool:
    nb = idx[u][valid[u]]
    return bool(np.any(labels[nb] == -1))


def _bfs_dist(idx, valid, source) -> np.ndarray:
    n = idx.shape[0]
    dist = np.full(n, np.iinfo(np.int32).max, dtype=np.int64)
    dist[source] = 0
    frontier = [source]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for v in idx[u][valid[u]]:
                v = int(v)
                if dist[v] > d:
                    dist[v] = d
                    nxt.append(v)
        frontier = nxt
    return dist


def refine_partition(idx: np.ndarray, w: np.ndarray, labels: np.ndarray, K: int,
                     passes: int = 3, balance_tol: float = 0.05) -> np.ndarray:
    """Boundary-flip refinement: move nodes to the majority neighbor partition
    when it reduces cut size and keeps balance within ``balance_tol``."""
    n, dmax = idx.shape
    labels = labels.copy()
    valid = w != 0
    lo = (1 - balance_tol) * n / K
    for _ in range(passes):
        moved = 0
        sizes = np.bincount(labels, minlength=K).astype(np.int64)
        for u in range(n):
            lu = labels[u]
            if sizes[lu] <= lo:
                continue
            nb = idx[u][valid[u]]
            if len(nb) == 0:
                continue
            nl = labels[nb]
            counts = np.bincount(nl, minlength=K)
            best = int(np.argmax(counts))
            if best != lu and counts[best] > counts[lu]:
                labels[u] = best
                sizes[lu] -= 1
                sizes[best] += 1
                moved += 1
        if moved == 0:
            break
    return labels
