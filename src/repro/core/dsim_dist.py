"""Distributed (shard_map) backend of the DSIM.

Each mesh device hosts one partition: local spins, shadow weights, and ghost
slots live device-local; the *only* collective during sampling is the
boundary-state exchange — an all-gather of the boundary spins, every
``sync_every`` sweeps.  This is the TPU-native realization of the paper's
"devices exchange nothing but 1-bit boundary states".

Semantics are identical to the stacked backend in :mod:`repro.core.dsim`
(verified in tests with a multi-device subprocess); the same
:class:`PartitionedProblem` feeds both.

Replicas: the engine runs R independent chains per call (fixed at
construction).  The replica axis sits between the partition axis and the
site axis — (K, R, n_max) — so the partition axis stays the sharded leading
dim and all R boundary payloads of one exchange travel in a single
all-gather.  R=1 states are bitwise identical to the legacy layout.

Precisions (mirroring the stacked engine plus the lattice engine's word
format):

* ``"f32"`` — floating reference (tanh + float compare; Philox or LFSR;
  boundary payloads bit-packed uint8 per replica by default).
* ``"int8"`` — the fixed-point pipeline: int8 shadow couplings, int32 field
  accumulation, LUT-threshold accepts against the raw 24-bit LFSR draw.
  Replica streams are seeded per replica (:func:`spawn_seeds`), so replica
  r is bitwise identical to replica r of the stacked int8 engine and is
  *prefix-stable* in R (growing the batch never reshuffles existing
  chains).
* ``"bitplane"`` — multi-spin coding over the int8 substrate: spins stored
  as (K, W, n_max) uint32 word planes, 32 replica lanes per word and
  W = ceil(R/32) stacked planes (lane l = word l//32, bit l%32).  The
  boundary all-gather ships the *native words* — 4 B per boundary site
  *per word plane*, with ZERO pack/unpack compute on the collective path
  (a word slice IS the wire payload) — and the phase update runs the
  bit-sliced carry-save adder tree over XOR'd sign planes with per-lane
  LFSR columns and the same LUT accept.  Lane (w, b) is bit-identical to
  replica ``w*32 + b`` of the unpacked int8 path at matched seeds, and
  prefix-stable in both b and w.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .dsim import PartitionedProblem, DSIMState
from .degrade import (DegradePolicy, MeshHealthMonitor, health_init,
                      wire_checksum)
from .annealing import ArraySchedule, beta_row_indices, beta_table
from .pbit import (FixedPoint, bitplane_planes, field_bound, flips_publish,
                   lfsr_init, lfsr_next, lfsr_uniform, lut_accept, quantize,
                   quantize_couplings, threshold_lut_cached)
from .packing import pack_pm1, unpack_pm1, pad_to_multiple, pack_lanes, \
    unpack_lanes, lane_coords
from .energy import energy as direct_energy
from repro.compat import shard_map
from repro.engines.base import (RecordedCursor, check_lanes,
                                run_recorded_driver, spawn_seeds)
from repro.kernels.ops import bitplane_gather_count_op

__all__ = ["DistDSIMEngine"]

SyncSpec = Union[int, str, None]


class DistDSIMEngine:
    """One partition per device along ``axis`` of ``mesh`` (K = axis size)."""

    def __init__(self, prob: PartitionedProblem, mesh: Mesh,
                 axis: Union[str, tuple] = "data",
                 rng: str = "philox", fmt: Optional[FixedPoint] = None,
                 mode: str = "dsim", bitpack: bool = True,
                 replicas: int = 1, precision: str = "f32",
                 degrade: Union[None, str, DegradePolicy] = None):
        axis_tuple = (axis,) if isinstance(axis, str) else tuple(axis)
        ndev = int(np.prod([mesh.shape[a] for a in axis_tuple]))
        if ndev != prob.K:
            raise ValueError(f"mesh axis size {ndev} != K={prob.K}")
        if mode not in ("dsim", "cmft"):
            raise ValueError(mode)
        if precision not in ("f32", "int8", "bitplane"):
            raise ValueError(f"unknown precision {precision!r}")
        if precision != "f32" and (rng != "lfsr" or mode != "dsim"):
            # the fixed-point/word paths are the hardware pipeline: per-p-bit
            # LFSRs (the LUT thresholds the raw 24-bit draw) and
            # instantaneous +-1 ghosts (cmft's fractional window-means fit
            # neither integer fields nor 1-bit lanes)
            raise ValueError(
                f"precision={precision!r} needs rng='lfsr', mode='dsim'")
        self.degrade = DegradePolicy.parse(degrade)
        if self.degrade is not None and mode != "dsim":
            # cmft publishes fractional window means — there is no 1-bit
            # wire representation to checksum, and held means are not a
            # meaningful last-known-good
            raise ValueError("degrade policies need mode='dsim'")
        self.health = (MeshHealthMonitor(self.degrade, prob.K,
                                         kind="partitions")
                       if self.degrade is not None else None)
        # host-scheduled engine-boundary fault codes (0 ok / 1 drop /
        # 2 corrupt), indexed by the traced exchange sequence number
        self._fault_codes = None
        # the shared lane-cap guard; W stacked word planes for the word path
        self.words = check_lanes(precision, replicas)
        self.p = prob
        self.mesh = mesh
        self.axis = axis_tuple if len(axis_tuple) > 1 else axis_tuple[0]
        self.rng_kind = rng
        self.fmt = fmt
        self.mode = mode
        self.precision = precision
        self.replicas = int(replicas)
        self.n_sites = prob.n
        # bit-packing needs b_max % 8 == 0; re-pad the packed pool coords
        self.b_pad = pad_to_multiple(prob.b_max, 8)
        self.bitpack = bitpack and mode == "dsim" and precision == "f32"
        self._shard = NamedSharding(mesh, P(self.axis))
        self._repl = NamedSharding(mesh, P())
        self._chunk_cache = {}
        self._energy = jax.jit(self._energy_impl)

        bs = np.asarray(prob.bnd_slots)
        pad = np.zeros((prob.K, self.b_pad - prob.b_max), dtype=bs.dtype)
        self._bnd_slots = jnp.asarray(np.concatenate([bs, pad], axis=1))
        gsp = np.asarray(prob.ghost_src_packed)
        gk, gc = gsp // prob.b_max, gsp % prob.b_max
        self._ghost_src_pool = jnp.asarray((gk * self.b_pad + gc).astype(np.int32))

        self._consts = dict(
            local_idx=prob.local_idx,
            color_slots=prob.color_slots, color_mask=prob.color_mask,
            bnd_slots=self._bnd_slots, ghost_src_pool=self._ghost_src_pool,
            # source partition of each ghost slot — the per-source
            # last-known-good hold mask of the degraded-mode exchange
            ghost_src_part=jnp.asarray(gk.astype(np.int32)),
        )
        if precision == "f32":
            self._consts.update(local_w=prob.local_w, local_h=prob.local_h)
        else:
            h_q, (w_q,), self.q_scale = quantize_couplings(
                prob.local_h, (prob.local_w,))
            wq = np.asarray(w_q)
            self.f_max = field_bound(
                h_q, tuple(wq[..., d] for d in range(wq.shape[-1])))
            self._lut_cache = {}
            if precision == "int8":
                self._consts.update(local_h_q=h_q, local_w_q=w_q)
            else:
                # per-direction sign/nonzero word planes + the lane-
                # independent LUT-column base (validates |w_q| <= 1)
                signs, nz, base, _ = bitplane_planes(
                    h_q, tuple(wq[..., d] for d in range(wq.shape[-1])))
                self._consts.update(
                    bp_signs=jnp.stack(signs, axis=-1),   # (K, n_max, D)
                    bp_nz=jnp.stack(nz, axis=-1),
                    bp_base=base)                          # (K, n_max)
                # lane l lives at word plane _lane_w[l], bit _lane_b[l]
                self._lane_w, self._lane_b = lane_coords(self.replicas, 1)

    def _lut_for(self, table: np.ndarray) -> jnp.ndarray:
        return threshold_lut_cached(self._lut_cache, table, self.q_scale,
                                    self.f_max, fmt=self.fmt)

    # -- state ------------------------------------------------------------------

    def init_state(self, seed: int = 0) -> DSIMState:
        p, R = self.p, self.replicas
        if self.precision != "f32":
            # per-replica seeding: replica r's spins and LFSR column depend
            # on spawn_seeds(seed, R)[r] alone, exactly like the stacked
            # int8 engine's batched init — so dist int8 replica r is
            # bitwise the stacked replica r, bitplane lane r is bitwise the
            # unpacked replica r, and lanes are prefix-stable in R
            ms, rngs = [], []
            for s_r in spawn_seeds(seed, R):
                key, sub = jax.random.split(jax.random.PRNGKey(s_r))
                ms.append(jnp.where(
                    jax.random.bernoulli(sub, 0.5, (p.K, p.n_max)),
                    1, -1).astype(jnp.int8))
                rngs.append(lfsr_init(p.K * p.n_max, s_r).reshape(p.K,
                                                                  p.n_max))
            m_r = jnp.stack(ms)                              # (R, K, n_max)
            rng = jnp.stack(rngs).transpose(1, 0, 2)         # (K, R, n_max)
            zero = jnp.zeros((), dtype=jnp.int32)
            flips = jnp.zeros((R,), jnp.int32)
            if self.precision == "bitplane":
                W = self.words
                mw = jnp.swapaxes(pack_lanes(m_r), 0, 1)     # (K, W, n_max)
                # per-word flat-pool gather, the word analogue of
                # _exchange_host's per-replica gather
                pool = jnp.swapaxes(mw, 0, 1).reshape(W, -1)
                ghosts = jnp.swapaxes(pool[:, p.ghost_src], 0, 1)
                st = DSIMState(m=mw, ghosts=ghosts,          # (K, W, g_max)
                               macc=jnp.zeros((p.K, 1), jnp.float32),
                               rng=rng, sweep=zero, flips=flips)
            else:
                m = m_r.transpose(1, 0, 2)                   # (K, R, n_max)
                st = DSIMState(m=m, ghosts=self._exchange_host(m),
                               macc=jnp.zeros((p.K, R, p.n_max),
                                              jnp.float32),
                               rng=rng, sweep=zero, flips=flips)
            return self.shard_state(st)
        key = jax.random.PRNGKey(seed)
        key, sub = jax.random.split(key)
        m = jnp.where(jax.random.bernoulli(sub, 0.5, (p.K, R, p.n_max)), 1, -1)
        m = m.astype(jnp.int8)
        if self.rng_kind == "philox":
            # legacy uint32[2] keys: split returns (K*R, 2) raw key rows
            rng = jax.random.split(key, p.K * R).reshape(p.K, R, 2)
        else:
            rng = lfsr_init(p.K * R * p.n_max, seed).reshape(p.K, R, p.n_max)
        ghosts = self._exchange_host(m)
        zero = jnp.zeros((), dtype=jnp.int32)
        st = DSIMState(m=m, ghosts=ghosts,
                       macc=jnp.zeros((p.K, R, p.n_max), jnp.float32),
                       rng=rng, sweep=zero,
                       flips=jnp.zeros((R,), jnp.int32))
        return self.shard_state(st)

    def shard_state(self, st: DSIMState) -> DSIMState:
        # re-sharding (init, restore from snapshot) invalidates the cached
        # exchange-only closure: it closed over constants placed for the
        # previous sharding, and a stale cache would let the eta probe run
        # against dead buffers
        self._exchange_only_fn = None
        put = lambda x: jax.device_put(x, self._shard)
        return DSIMState(m=put(st.m), ghosts=put(st.ghosts), macc=put(st.macc),
                         rng=put(st.rng),
                         sweep=jax.device_put(st.sweep, self._repl),
                         flips=jax.device_put(st.flips, self._repl))

    def _exchange_host(self, m) -> jnp.ndarray:
        # m (K, R, n_max): ghost_src indexes the flat (K * n_max) pool per
        # replica — gather per replica on the replica-transposed view
        R = self.replicas
        flat = m.transpose(1, 0, 2).reshape(R, -1).astype(jnp.float32)
        ghosts = flat[:, self.p.ghost_src]            # (R, K, g_max)
        return ghosts.transpose(1, 0, 2)              # (K, R, g_max)

    # -- device-local block functions (run inside shard_map) -----------------------
    # All block arrays have their partition dim squeezed away: m (R, n_max)
    # int8 — or (W, n_max) uint32 word planes on the bitplane path —,
    # ghosts (R, g_max) | (W, g_max) words, rng (R,) keys | (R, n_max)
    # LFSR, consts rows (…).

    def _exchange_block(self, m, macc, S, consts, inst: bool = False):
        """Publish boundary states, all-gather, gather this device's ghosts.

        ``inst`` forces instantaneous +-1 states even in cmft mode — the
        per-phase refresh path, matching the stacked engine's
        ``_exchange_inst``.  (Publishing ``macc/1`` there was wrong: the
        accumulator is zeroed at every S-sweep boundary, so the first
        phases of each iteration would broadcast all-zero ghost means.)
        """
        R = self.replicas
        bnd_slots = consts["bnd_slots"]                       # (b_pad,)
        if self.mode == "cmft" and not inst:
            vals = (macc / jnp.float32(S))[:, bnd_slots]      # (R, b_pad)
            pool = jax.lax.all_gather(vals, self.axis, tiled=True)
        elif self.bitpack:
            bnd = m[:, bnd_slots]                             # (R, b_pad)
            packed = pack_pm1(bnd)                            # (R, b_pad/8)
            pool_p = jax.lax.all_gather(packed, self.axis, tiled=True)
            pool = unpack_pm1(pool_p, self.b_pad).astype(jnp.float32)
        else:
            bnd = m[:, bnd_slots]
            pool = jax.lax.all_gather(bnd, self.axis,
                                      tiled=True).astype(jnp.float32)
        # pool (K*R, b_pad) device-order-major -> (R, K*b_pad) per replica
        pool = pool.reshape(self.p.K, R, self.b_pad)
        pool = pool.transpose(1, 0, 2).reshape(R, -1)
        return pool[:, consts["ghost_src_pool"]]              # (R, g_max)

    def _exchange_block_w(self, mw, consts):
        """Native-word boundary exchange: a slice of the spin words IS the
        wire payload — 4 B per boundary site per word plane (32 lanes each),
        no pack/unpack compute anywhere on the collective path.  ``mw`` is
        the device-local (W, n_max); the all-gather ships all W planes of
        the boundary in one collective."""
        W = int(mw.shape[0])
        bnd = mw[:, consts["bnd_slots"]]                      # (W, b_pad)
        pool = jax.lax.all_gather(bnd, self.axis, tiled=True)  # (K*W, b_pad)
        pool = pool.reshape(self.p.K, W, self.b_pad)
        pool = jnp.swapaxes(pool, 0, 1).reshape(W, -1)        # (W, K*b_pad)
        return pool[:, consts["ghost_src_pool"]]              # (W, g_max)

    def boundary_exchange_fn(self):
        """Jitted exchange-ONLY closure: exactly the ``_exchange_block*``
        collective (publish -> all-gather -> ghost gather) with every
        p-bit update elided.  ``fn(state) -> ghosts`` on live state — the
        measured-η probe (``obs.EtaMeter.measure_exchange`` times it to
        get t_exchange, hence f_comm, without touching the run path)."""
        cached = getattr(self, "_exchange_only_fn", None)
        if cached is not None:
            return cached
        spec_m = P(self.axis)
        cspec = jax.tree.map(lambda _: spec_m, self._consts)
        word = self.precision == "bitplane"

        def block(m, macc, consts):
            m, macc = m[0], macc[0]
            consts = jax.tree.map(lambda x: x[0], consts)
            if word:
                g = self._exchange_block_w(m, consts)
            else:
                g = self._exchange_block(m, macc, 1, consts)
            return g[None]

        smapped = shard_map(block, mesh=self.mesh,
                            in_specs=(spec_m, spec_m, cspec),
                            out_specs=spec_m, check_vma=False)
        run = jax.jit(lambda m, macc: smapped(m, macc, self._consts))
        fn = lambda state: run(state.m, state.macc)  # noqa: E731
        self._exchange_only_fn = fn
        return fn

    def _phase_block(self, c, m, ghosts, rng, beta, consts, lut=None):
        """One color phase; ``beta`` is the f32 inverse temperature — or,
        with ``lut``, the int32 LUT row index the staircase resolved to."""
        slots, mask = consts["color_slots"][c], consts["color_mask"][c]  # (nc,)
        idx_c = consts["local_idx"][slots]                    # (nc, D)
        int8 = lut is not None
        acc = jnp.int32 if int8 else jnp.float32
        h_c = (consts["local_h_q"] if int8 else consts["local_h"])[slots] \
            .astype(acc)
        w_c = (consts["local_w_q"] if int8 else consts["local_w"])[slots] \
            .astype(acc)
        mext = jnp.concatenate([m.astype(acc), ghosts.astype(acc)], axis=1)
        nbr = jnp.take(mext, idx_c, axis=1)                   # (R, nc, D)
        field = h_c + (w_c * nbr).sum(axis=-1)                # (R, nc)
        if self.rng_kind == "philox":
            ks = jax.vmap(jax.random.split)(rng)              # (R, 2) keys
            rng = ks[:, 0]
            nc = field.shape[1]
            r = jax.vmap(lambda k: jax.random.uniform(
                k, (nc,), minval=-1.0, maxval=1.0))(ks[:, 1])
        else:
            s = rng[:, slots]
            s = lfsr_next(s)
            # int8 accepts draw raw bits from s — skip the f32 uniform so
            # the integer body stays float-free (contract rule IR-A)
            r = None if int8 else lfsr_uniform(s)
            rng = rng.at[:, slots].set(s)
        old = m[:, slots]
        if int8:
            # pure-integer accept: raw 24-bit draw vs tabulated threshold
            u = s >> jnp.uint32(8)
            thr = jax.lax.dynamic_index_in_dim(
                lut, jnp.asarray(beta, jnp.int32), axis=0, keepdims=False)
            new = jnp.where(lut_accept(thr, field, self.f_max, u),
                            1, -1).astype(jnp.int8)
        else:
            act = quantize(beta * field, self.fmt)
            new = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
        new = jnp.where(mask, new, old)
        flips = (new != old).sum(axis=1).astype(jnp.int32)    # (R,)
        m = m.at[:, slots].set(new)
        return m, rng, flips

    def _phase_block_w(self, c, mw, ghosts_w, rng, row, consts, lut):
        """One color phase on packed words: XOR sign application, bit-sliced
        adder tree for the +1-contribution count, per-lane LFSR draw + LUT
        accept.  ``mw``/``ghosts_w`` carry the leading W word-plane axis;
        lane l reads word ``_lane_w[l]`` at bit ``_lane_b[l]``, and the
        accepted bits scatter back per word (disjoint bit positions, so the
        adds are bitwise ORs).  Lane (w, b) is bit-identical to replica
        ``w*32 + b`` of :meth:`_phase_block` on the int8 path (same integer
        field, same LFSR column, same threshold compare)."""
        slots, mask = consts["color_slots"][c], consts["color_mask"][c]
        mext = jnp.concatenate([mw, ghosts_w], axis=-1)       # (W, n_ext)
        counts = bitplane_gather_count_op(
            mext, consts["local_idx"][slots], consts["bp_signs"][slots],
            consts["bp_nz"][slots])                           # (W, nc) each
        wl, bl = self._lane_w, self._lane_b                   # (R,), (R, 1)
        one = jnp.uint32(1)
        s = rng[:, slots]
        s = lfsr_next(s)
        rng = rng.at[:, slots].set(s)
        u = s >> jnp.uint32(8)                                # (R, nc)
        cnt = jnp.zeros(u.shape, jnp.int32)
        for i, b in enumerate(counts):
            cnt = cnt + (((b[wl] >> bl) & one)
                         << jnp.uint32(i)).astype(jnp.int32)
        # f = h_q + 2c - nnz = (base - f_max) + 2c, per lane
        field = consts["bp_base"][slots][None, :] - self.f_max + 2 * cnt
        thr = jax.lax.dynamic_index_in_dim(
            lut, jnp.asarray(row, jnp.int32), axis=0, keepdims=False)
        accept = lut_accept(thr, field, self.f_max, u)        # (R, nc)
        upd = jnp.zeros((int(mw.shape[0]), u.shape[1]), jnp.uint32) \
            .at[wl].add(accept.astype(jnp.uint32) << bl)      # (W, nc)
        old = mw[:, slots]
        new = jnp.where(mask, upd, old)
        diff = old ^ new
        flips = ((diff[wl] >> bl) & one).astype(jnp.int32) \
            .sum(axis=1)                                      # (R,)
        mw = mw.at[:, slots].set(new)
        return mw, rng, flips

    def _iteration_block(self, m, ghosts, macc, rng, flips, betas_S, sync,
                         consts, lut=None):
        S = betas_S.shape[0]

        def body(carry, beta):
            m, ghosts, macc, rng, flips = carry
            for c in range(len(consts["color_slots"])):
                if sync == "phase":
                    ghosts = self._exchange_block(m, macc, 1, consts,
                                                  inst=True)
                m, rng, f = self._phase_block(c, m, ghosts, rng, beta,
                                              consts, lut)
                flips = flips + f.astype(flips.dtype)
            if self.mode == "cmft":
                # dsim mode never reads the window accumulator — keeping
                # the add there would put dead f32 arithmetic in the int8
                # chunk body (contract rule IR-A)
                macc = macc + m.astype(jnp.float32)
            return (m, ghosts, macc, rng, flips), None

        (m, ghosts, macc, rng, flips), _ = jax.lax.scan(
            body, (m, ghosts, macc, rng, flips), betas_S)
        if sync not in ("phase", None):
            ghosts = self._exchange_block(m, macc, S, consts)
        macc = jnp.zeros_like(macc)
        return m, ghosts, macc, rng, flips

    def _iteration_block_w(self, mw, ghosts, macc, rng, flips, rows_S, sync,
                           consts, lut):
        def body(carry, row):
            mw, ghosts, rng, flips = carry
            for c in range(len(consts["color_slots"])):
                if sync == "phase":
                    ghosts = self._exchange_block_w(mw, consts)
                mw, rng, f = self._phase_block_w(c, mw, ghosts, rng, row,
                                                 consts, lut)
                flips = flips + f.astype(flips.dtype)
            return (mw, ghosts, rng, flips), None

        (mw, ghosts, rng, flips), _ = jax.lax.scan(
            body, (mw, ghosts, rng, flips), rows_S)
        if sync not in ("phase", None):
            ghosts = self._exchange_block_w(mw, consts)
        return mw, ghosts, macc, rng, flips

    # -- degraded-mode exchange (integrity header + stale hold) ---------------------

    def _exchange_block_checked(self, m, consts, ghosts_prev, health,
                                codes, freeze: bool):
        """The boundary exchange with the integrity layer on.

        Every device publishes its payload plus a ``[seq, checksum]``
        header; the receiver recomputes the checksum of each source's
        slice of the gathered pool and compares.  A source that fails
        (wrong checksum, wrong/missing sequence number) has ALL its ghost
        entries held at the carried last-known-good values — a bad
        exchange is detected and *not ingested*.  With zero detections the
        ingested ghosts are bitwise the unchecked `_exchange_block*`
        values.  ``codes`` (optional, host-scheduled via
        :meth:`set_exchange_faults`) corrupts/drops the *received* pool at
        indexed sequence numbers — the engine-boundary fault site; the
        detection below derives only from the wire contents.
        """
        seq, stale, frozen, det, held, maxst = health
        K = self.p.K
        word = self.precision == "bitplane"
        lanes = int(m.shape[0])                   # W word planes | R chains
        bnd_slots = consts["bnd_slots"]
        if word:
            bnd = m[:, bnd_slots]                 # (W, b_pad) uint32
            pool = jax.lax.all_gather(bnd, self.axis, tiled=True)
            wire = pool.reshape(K, lanes, self.b_pad)
            sent = bnd
        elif self.bitpack:
            bnd = m[:, bnd_slots]                 # (R, b_pad) int8
            packed = pack_pm1(bnd)
            pool_p = jax.lax.all_gather(packed, self.axis, tiled=True)
            pool = unpack_pm1(pool_p, self.b_pad).astype(jnp.float32)
            wire = jax.lax.bitcast_convert_type(
                pool.reshape(K, lanes, self.b_pad), jnp.uint32)
            sent = bnd.astype(jnp.float32)
        else:
            # int8 boundary states ARE the wire (1 B/site, the declared
            # boundary_payload); widening to f32 happens AFTER the gather
            bnd = m[:, bnd_slots]                 # (R, b_pad) int8
            pool = jax.lax.all_gather(bnd, self.axis, tiled=True)
            wire = pool.reshape(K, lanes, self.b_pad)
            sent = bnd
        # header: my exchange counter + the checksum of what I published
        hdr = jnp.stack([seq, wire_checksum(sent)])
        hdrs = jax.lax.all_gather(hdr, self.axis, tiled=True).reshape(K, 2)
        if codes is not None:
            # engine-boundary fault injection on the RECEIVED pool: the
            # detection below sees only the (possibly damaged) wire bits
            total = jnp.uint32(codes.shape[0])
            code = jnp.where(
                seq < total,
                codes[jnp.clip(seq, 0, total - 1).astype(jnp.int32)], 0)
            corrupt, drop = code == 2, code == 1
            flip = jnp.asarray(2 if wire.dtype == jnp.int8 else 0x00400000,
                               wire.dtype)
            wire = jnp.where(corrupt, wire ^ flip, wire)
            wire = jnp.where(drop, jnp.zeros_like(wire), wire)
            hdrs = jnp.where(drop, jnp.full_like(hdrs, 0xFFFFFFFF), hdrs)
        ck_k = jax.vmap(wire_checksum)(wire)                     # (K,)
        ok_k = (ck_k == hdrs[:, 1]) & (hdrs[:, 0] == seq)
        if freeze:
            frozen = jnp.maximum(frozen,
                                 (~ok_k).any().astype(jnp.int32))
            bad_k = (~ok_k) | (frozen > 0)
        else:
            bad_k = ~ok_k
        det = det + (~ok_k).any().astype(jnp.int32)
        held = held + bad_k.any().astype(jnp.int32)
        stale = jnp.where(bad_k, stale + 1, 0)
        maxst = jnp.maximum(maxst, stale.max())
        seq = seq + jnp.uint32(1)
        # ingest per source: held sources keep last-known-good ghosts
        if word:
            vals = wire
        elif wire.dtype == jnp.int8:
            vals = wire.astype(jnp.float32)       # widen off the wire
        else:
            vals = jax.lax.bitcast_convert_type(wire, jnp.float32)
        pool2 = vals.transpose(1, 0, 2).reshape(lanes, -1)
        ghosts_new = pool2[:, consts["ghost_src_pool"]]
        bad_entry = bad_k[consts["ghost_src_part"]]              # (g_max,)
        ghosts = jnp.where(bad_entry[None, :], ghosts_prev, ghosts_new)
        return ghosts, (seq, stale, frozen, det, held, maxst)

    def _iteration_block_deg(self, m, ghosts, macc, rng, flips, betas_S,
                             consts, health, codes, freeze, lut=None):
        """S sweeps (no inline exchange) + one checked boundary exchange."""
        if self.precision == "bitplane":
            m, _, macc, rng, flips = self._iteration_block_w(
                m, ghosts, macc, rng, flips, betas_S, None, consts, lut)
        else:
            m, _, macc, rng, flips = self._iteration_block(
                m, ghosts, macc, rng, flips, betas_S, None, consts, lut)
        ghosts, health = self._exchange_block_checked(
            m, consts, ghosts, health, codes, freeze)
        return m, ghosts, macc, rng, flips, health

    # -- runners --------------------------------------------------------------------

    def _run_chunk(self, iters: int, S: int, sync: SyncSpec):
        key = (iters, S, sync)
        if key in self._chunk_cache:
            return self._chunk_cache[key]

        spec_m = P(self.axis)
        cspec = jax.tree.map(lambda _: spec_m, self._consts)
        has_lut = self.precision != "f32"
        word = self.precision == "bitplane"

        def block(m, ghosts, macc, rng, flips_in, betas, consts, *lut_opt):
            # squeeze the device-local partition dim from state and consts
            m, ghosts, macc, rng = m[0], ghosts[0], macc[0], rng[0]
            consts = jax.tree.map(lambda x: x[0], consts)
            lut = lut_opt[0] if lut_opt else None
            # per-chunk flips accumulate in uint32 (modular-exact at any
            # magnitude): the old int32 accumulator overflowed *within* a
            # single long chunk at ~2.1e9 lane-flips, before the psum and
            # the driver's mod-2^32 odometer read ever saw it
            local = jnp.zeros(flips_in.shape, jnp.uint32)

            def it(carry, b):
                m, ghosts, macc, rng, fl = carry
                if word:
                    out = self._iteration_block_w(m, ghosts, macc, rng, fl,
                                                  b, sync, consts, lut)
                else:
                    out = self._iteration_block(m, ghosts, macc, rng, fl, b,
                                                sync, consts, lut)
                return out, None
            (m, ghosts, macc, rng, local), _ = jax.lax.scan(
                it, (m, ghosts, macc, rng, local), betas)
            total = jax.lax.psum(local, self.axis)
            flips = flips_publish(flips_in, total)
            return m[None], ghosts[None], macc[None], rng[None], flips

        in_specs = (spec_m, spec_m, spec_m, spec_m, P(), P(), cspec)
        if has_lut:
            in_specs = in_specs + (P(),)
        smapped = shard_map(
            block, mesh=self.mesh,
            in_specs=in_specs,
            out_specs=(spec_m, spec_m, spec_m, spec_m, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: DSIMState, betas, consts, *lut_opt):
            m, ghosts, macc, rng, flips = smapped(
                state.m, state.ghosts, state.macc, state.rng, state.flips,
                betas, consts, *lut_opt)
            return DSIMState(m=m, ghosts=ghosts, macc=macc, rng=rng,
                             sweep=state.sweep + betas.shape[0] * betas.shape[1],
                             flips=flips)

        self._chunk_cache[key] = run
        return run

    def _run_chunk_deg(self, iters: int, S: int, freeze: bool,
                       has_codes: bool):
        """Chunk runner with the integrity layer on: threads the health
        carry through the iteration scan and runs the checked exchange.
        Needs an integer ``sync_every`` (one exchange per S sweeps)."""
        key = ("deg", iters, S, freeze, has_codes)
        if key in self._chunk_cache:
            return self._chunk_cache[key]

        spec_m = P(self.axis)
        cspec = jax.tree.map(lambda _: spec_m, self._consts)
        has_lut = self.precision != "f32"
        hspec = tuple(P() for _ in range(6))

        def block(m, ghosts, macc, rng, flips_in, betas, consts, health,
                  *rest):
            m, ghosts, macc, rng = m[0], ghosts[0], macc[0], rng[0]
            consts = jax.tree.map(lambda x: x[0], consts)
            codes = rest[0] if has_codes else None
            lut = rest[-1] if has_lut else None
            local = jnp.zeros(flips_in.shape, jnp.uint32)

            def it(carry, b):
                m, ghosts, macc, rng, fl, health = carry
                out = self._iteration_block_deg(m, ghosts, macc, rng, fl,
                                                b, consts, health, codes,
                                                freeze, lut)
                return out, None
            (m, ghosts, macc, rng, local, health), _ = jax.lax.scan(
                it, (m, ghosts, macc, rng, local, health), betas)
            total = jax.lax.psum(local, self.axis)
            flips = flips_publish(flips_in, total)
            return m[None], ghosts[None], macc[None], rng[None], flips, \
                health

        in_specs = (spec_m, spec_m, spec_m, spec_m, P(), P(), cspec, hspec)
        if has_codes:
            in_specs = in_specs + (P(),)
        if has_lut:
            in_specs = in_specs + (P(),)
        smapped = shard_map(
            block, mesh=self.mesh,
            in_specs=in_specs,
            out_specs=(spec_m, spec_m, spec_m, spec_m, P(), hspec),
            check_vma=False,
        )

        @jax.jit
        def run(state: DSIMState, betas, consts, health, *rest):
            m, ghosts, macc, rng, flips, health = smapped(
                state.m, state.ghosts, state.macc, state.rng, state.flips,
                betas, consts, health, *rest)
            st = DSIMState(
                m=m, ghosts=ghosts, macc=macc, rng=rng,
                sweep=state.sweep + betas.shape[0] * betas.shape[1],
                flips=flips)
            return st, health

        self._chunk_cache[key] = run
        return run

    def set_exchange_faults(self, codes):
        """Schedule engine-boundary exchange faults: ``codes[seq]`` in
        {0 ok, 1 drop, 2 corrupt} applied to the *received* pool at global
        exchange ``seq`` (see ``serve.faults.FaultPlan.exchange_codes``).
        ``None`` clears.  Requires a degrade policy — an unchecked engine
        would silently ingest the damage, which is exactly the failure
        mode this subsystem removes."""
        if codes is None:
            self._fault_codes = None
            return
        if self.degrade is None:
            raise ValueError("set_exchange_faults needs a degrade policy "
                             "(unchecked engines must not ingest damage)")
        self._fault_codes = jnp.asarray(np.asarray(codes), jnp.int32)

    def resync(self, state: DSIMState) -> DSIMState:
        """Quarantine exit: instantaneous full-boundary refresh.

        Recomputes every ghost from the *current* spins — exactly the
        exchange a no-fault run would have performed at this point, so the
        returned ghosts are bitwise the no-fault trajectory's (verified in
        tests).  Clears staleness/freeze on the health monitor."""
        ghosts = self.boundary_exchange_fn()(state)
        if self.health is not None:
            self.health.on_resync()
        return dataclasses.replace(state, ghosts=ghosts)

    def run_recorded_full(self, state: DSIMState, schedule,
                          record_points: Sequence[int], *,
                          cursor: bool = False,
                          sync_every: SyncSpec = 1):
        """Shared-driver runner; returns (state, RunRecord) — or, with
        ``cursor=True``, the resumable RecordedCursor."""
        sync = sync_every if sync_every in ("phase", None) else int(sync_every)

        deg = self.degrade is not None
        if deg and sync in ("phase", None):
            raise ValueError("degrade policies need an integer sync_every "
                             "(one checked exchange per S sweeps)")
        if deg:
            self.health.reset()
            codes = self._fault_codes
            freeze = self.degrade.mode == "freeze_boundary"
            has_codes = codes is not None

        if self.precision != "f32":
            # the staircase becomes LUT row indices (beta is in the table)
            beta_arr = np.asarray(schedule.beta_array(), np.float32)
            table = beta_table(beta_arr)
            lut = self._lut_for(table)
            sched = ArraySchedule(beta_row_indices(beta_arr, table))

            if deg:
                def chunk(st, rows2d, iters, S):
                    rest = ((codes,) if has_codes else ()) + (lut,)
                    st, carry = self._run_chunk_deg(
                        iters, S, freeze, has_codes)(
                            st, rows2d, self._consts,
                            self.health.carry, *rest)
                    self.health.update(carry, exchanges=iters)
                    return st
            else:
                def chunk(st, rows2d, iters, S):
                    return self._run_chunk(iters, S, sync)(st, rows2d,
                                                           self._consts, lut)
        else:
            sched = schedule

            if deg:
                def chunk(st, betas2d, iters, S):
                    rest = (codes,) if has_codes else ()
                    st, carry = self._run_chunk_deg(
                        iters, S, freeze, has_codes)(
                            st, betas2d, self._consts,
                            self.health.carry, *rest)
                    self.health.update(carry, exchanges=iters)
                    return st
            else:
                def chunk(st, betas2d, iters, S):
                    return self._run_chunk(iters, S, sync)(st, betas2d,
                                                           self._consts)

        kw = dict(
            state=state, schedule=sched, record_points=record_points,
            chunk_fn=chunk, record_fn=self.energy, sync_every=sync_every,
            flips_of=lambda st: st.flips,
            flips_per_sweep=self.p.n * self.replicas)
        if cursor:
            return RecordedCursor(**kw)
        return run_recorded_driver(**kw)

    def run_recorded(self, state: DSIMState, schedule,
                     record_points: Sequence[int], sync_every: SyncSpec = 1):
        """Run to each record point; returns (state, (times, energies))."""
        return self.run_recorded_full(state, schedule, record_points,
                                      sync_every=sync_every)

    # -- observables -------------------------------------------------------------------

    def global_spins(self, state: DSIMState) -> jnp.ndarray:
        """(R, N) global spins; squeezed to (N,) when replicas == 1."""
        p, R = self.p, self.replicas

        def one(m_r):                                     # (K, n_max)
            buf = jnp.ones((p.n + 1,), dtype=jnp.int8)
            buf = buf.at[p.global_ids.reshape(-1)].set(m_r.reshape(-1))
            return buf[: p.n]

        if self.precision == "bitplane":
            m_r = unpack_lanes(jnp.swapaxes(state.m, 0, 1), R)  # (R, K, n_max)
        else:
            m_r = state.m.transpose(1, 0, 2)
        spins = jax.vmap(one)(m_r)
        return spins[0] if R == 1 else spins

    def _energy_impl(self, state: DSIMState) -> jnp.ndarray:
        spins = self.global_spins(state)
        if self.replicas == 1:
            return direct_energy(self.p.graph, spins)
        return jax.vmap(lambda m: direct_energy(self.p.graph, m))(spins)

    def energy(self, state: DSIMState) -> jnp.ndarray:
        """(R,) true global energies (scalar when replicas == 1)."""
        return self._energy(state)

    def boundary_payload(self) -> dict:
        """Wire-format accounting of one boundary publication per device:
        dtype, total bytes, and bytes per boundary site covering ALL
        replicas/lanes (the roofline collective term and the benchmark's
        recorded payload)."""
        R = self.replicas
        if self.precision == "bitplane":
            W = self.words
            return {"dtype": "uint32", "bytes": 4 * W * self.b_pad,
                    "bytes_per_site_all_chains": 4.0 * W, "chains": R,
                    "word_planes": W, "bytes_per_site_per_word": 4.0,
                    "pack_compute": "none"}
        if self.mode == "cmft":
            return {"dtype": "float32", "bytes": 4 * R * self.b_pad,
                    "bytes_per_site_all_chains": 4.0 * R, "chains": R,
                    "pack_compute": "none"}
        if self.bitpack:
            return {"dtype": "uint8-bitmap", "bytes": R * self.b_pad // 8,
                    "bytes_per_site_all_chains": R / 8.0, "chains": R,
                    "pack_compute": "pack+unpack per exchange"}
        return {"dtype": "int8", "bytes": R * self.b_pad,
                "bytes_per_site_all_chains": float(R), "chains": R,
                "pack_compute": "none"}

    # -- dry-run / audit hooks -----------------------------------------------------------

    def _chunk_args(self, iters: int, S: int, sync: SyncSpec,
                    degrade: bool = False, freeze: bool = False,
                    has_codes: bool = False):
        """(runner, abstract args) for one sampling chunk — shared by the
        lowering dry-run and the static contract auditor's tracer.  With
        ``degrade`` the checked-exchange runner (health carry, optional
        fault-code operand) is selected instead of the plain one."""
        p, R = self.p, self.replicas

        def sds(x, shard):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=shard)

        zero = jnp.zeros((), jnp.int32)
        flips = jnp.zeros((R,), jnp.int32)
        if self.precision == "bitplane":
            st = DSIMState(
                m=jax.ShapeDtypeStruct((p.K, self.words, p.n_max),
                                       jnp.uint32, sharding=self._shard),
                ghosts=jax.ShapeDtypeStruct((p.K, self.words, p.g_max),
                                            jnp.uint32,
                                            sharding=self._shard),
                macc=jax.ShapeDtypeStruct((p.K, 1), jnp.float32,
                                          sharding=self._shard),
                rng=jax.ShapeDtypeStruct((p.K, R, p.n_max), jnp.uint32,
                                         sharding=self._shard),
                sweep=sds(zero, self._repl),
                flips=sds(flips, self._repl),
            )
        else:
            rng_t = jax.random.split(jax.random.PRNGKey(0),
                                     p.K * R).reshape(p.K, R, 2) \
                if self.rng_kind == "philox" else \
                jnp.zeros((p.K, R, p.n_max), jnp.uint32)
            st = DSIMState(
                m=jax.ShapeDtypeStruct((p.K, R, p.n_max), jnp.int8,
                                       sharding=self._shard),
                ghosts=jax.ShapeDtypeStruct((p.K, R, p.g_max), jnp.float32,
                                            sharding=self._shard),
                macc=jax.ShapeDtypeStruct((p.K, R, p.n_max), jnp.float32,
                                          sharding=self._shard),
                rng=sds(rng_t, self._shard),
                sweep=sds(zero, self._repl),
                flips=sds(flips, self._repl),
            )
        consts = jax.tree.map(lambda x: sds(x, self._shard), self._consts)
        sched_dt = jnp.float32 if self.precision == "f32" else jnp.int32
        sched = jax.ShapeDtypeStruct((iters, S), sched_dt,
                                     sharding=self._repl)
        lut_opt = () if self.precision == "f32" else (
            jax.ShapeDtypeStruct((1, 2 * self.f_max + 1), jnp.uint32,
                                 sharding=self._repl),)
        if not degrade:
            return self._run_chunk(iters, S, sync), \
                (st, sched, consts) + lut_opt
        health = tuple(
            jax.ShapeDtypeStruct(np.shape(h), np.asarray(h).dtype,
                                 sharding=self._repl)
            for h in health_init(p.K))
        codes_opt = (jax.ShapeDtypeStruct((8,), jnp.uint32,
                                          sharding=self._repl),) \
            if has_codes else ()
        run = self._run_chunk_deg(iters, S, freeze, has_codes)
        return run, (st, sched, consts, health) + codes_opt + lut_opt

    def lower_chunk(self, iters: int = 4, S: int = 4, sync: SyncSpec = 4):
        """Lower (not run) one sampling chunk — used by the launch dry-run."""
        run, args = self._chunk_args(iters, S, sync)
        return run.lower(*args)

    def trace_chunk(self, iters: int = 4, S: int = 4, sync: SyncSpec = 4,
                    degrade: bool = False, freeze: bool = False,
                    has_codes: bool = False):
        """Trace (not lower) one sampling chunk and return the jitted
        runner's Traced object, whose ``.jaxpr`` the static contract
        auditor walks.  Unlike :meth:`lower_chunk` this works over an
        ``AbstractMesh`` — collective dtype/count contracts are auditable
        on a single-device host, no multi-device subprocess needed."""
        run, args = self._chunk_args(iters, S, sync, degrade=degrade,
                                     freeze=freeze, has_codes=has_codes)
        return run.trace(*args)
