"""Distributed (shard_map) backend of the DSIM.

Each mesh device hosts one partition: local spins, shadow weights, and ghost
slots live device-local; the *only* collective during sampling is the
boundary-state exchange — an all-gather of 1-bit-packed boundary spins, every
``sync_every`` sweeps.  This is the TPU-native realization of the paper's
"devices exchange nothing but 1-bit boundary states".

Semantics are identical to the stacked backend in :mod:`repro.core.dsim`
(verified in tests with a multi-device subprocess); the same
:class:`PartitionedProblem` feeds both.

Replicas: the engine runs R independent chains per call (fixed at
construction).  The replica axis sits between the partition axis and the
site axis — (K, R, n_max) — so the partition axis stays the sharded leading
dim and all R boundary payloads of one exchange travel in a single
all-gather.  R=1 states are bitwise identical to the legacy layout.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .dsim import PartitionedProblem, DSIMState
from .pbit import FixedPoint, quantize, lfsr_init, lfsr_next, lfsr_uniform
from .packing import pack_pm1, unpack_pm1, pad_to_multiple
from .energy import energy as direct_energy
from repro.compat import shard_map
from repro.engines.base import RecordedCursor, run_recorded_driver

__all__ = ["DistDSIMEngine"]

SyncSpec = Union[int, str, None]


class DistDSIMEngine:
    """One partition per device along ``axis`` of ``mesh`` (K = axis size)."""

    def __init__(self, prob: PartitionedProblem, mesh: Mesh,
                 axis: Union[str, tuple] = "data",
                 rng: str = "philox", fmt: Optional[FixedPoint] = None,
                 mode: str = "dsim", bitpack: bool = True,
                 replicas: int = 1):
        axis_tuple = (axis,) if isinstance(axis, str) else tuple(axis)
        ndev = int(np.prod([mesh.shape[a] for a in axis_tuple]))
        if ndev != prob.K:
            raise ValueError(f"mesh axis size {ndev} != K={prob.K}")
        if mode not in ("dsim", "cmft"):
            raise ValueError(mode)
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.p = prob
        self.mesh = mesh
        self.axis = axis_tuple if len(axis_tuple) > 1 else axis_tuple[0]
        self.rng_kind = rng
        self.fmt = fmt
        self.mode = mode
        self.replicas = int(replicas)
        self.n_sites = prob.n
        # bit-packing needs b_max % 8 == 0; re-pad the packed pool coords
        self.b_pad = pad_to_multiple(prob.b_max, 8)
        self.bitpack = bitpack and mode == "dsim"
        self._shard = NamedSharding(mesh, P(self.axis))
        self._repl = NamedSharding(mesh, P())
        self._chunk_cache = {}
        self._energy = jax.jit(self._energy_impl)

        bs = np.asarray(prob.bnd_slots)
        pad = np.zeros((prob.K, self.b_pad - prob.b_max), dtype=bs.dtype)
        self._bnd_slots = jnp.asarray(np.concatenate([bs, pad], axis=1))
        gsp = np.asarray(prob.ghost_src_packed)
        gk, gc = gsp // prob.b_max, gsp % prob.b_max
        self._ghost_src_pool = jnp.asarray((gk * self.b_pad + gc).astype(np.int32))

        self._consts = dict(
            local_idx=prob.local_idx, local_w=prob.local_w, local_h=prob.local_h,
            color_slots=prob.color_slots, color_mask=prob.color_mask,
            bnd_slots=self._bnd_slots, ghost_src_pool=self._ghost_src_pool,
        )

    # -- state ------------------------------------------------------------------

    def init_state(self, seed: int = 0) -> DSIMState:
        p, R = self.p, self.replicas
        key = jax.random.PRNGKey(seed)
        key, sub = jax.random.split(key)
        m = jnp.where(jax.random.bernoulli(sub, 0.5, (p.K, R, p.n_max)), 1, -1)
        m = m.astype(jnp.int8)
        if self.rng_kind == "philox":
            rng = jax.random.split(key, p.K * R).reshape(p.K, R)
        else:
            rng = lfsr_init(p.K * R * p.n_max, seed).reshape(p.K, R, p.n_max)
        ghosts = self._exchange_host(m)
        zero = jnp.zeros((), dtype=jnp.int32)
        st = DSIMState(m=m, ghosts=ghosts,
                       macc=jnp.zeros((p.K, R, p.n_max), jnp.float32),
                       rng=rng, sweep=zero,
                       flips=jnp.zeros((R,), jnp.int32))
        return self.shard_state(st)

    def shard_state(self, st: DSIMState) -> DSIMState:
        put = lambda x: jax.device_put(x, self._shard)
        return DSIMState(m=put(st.m), ghosts=put(st.ghosts), macc=put(st.macc),
                         rng=put(st.rng),
                         sweep=jax.device_put(st.sweep, self._repl),
                         flips=jax.device_put(st.flips, self._repl))

    def _exchange_host(self, m) -> jnp.ndarray:
        # m (K, R, n_max): ghost_src indexes the flat (K * n_max) pool per
        # replica — gather per replica on the replica-transposed view
        R = self.replicas
        flat = m.transpose(1, 0, 2).reshape(R, -1).astype(jnp.float32)
        ghosts = flat[:, self.p.ghost_src]            # (R, K, g_max)
        return ghosts.transpose(1, 0, 2)              # (K, R, g_max)

    # -- device-local block functions (run inside shard_map) -----------------------
    # All block arrays have their partition dim squeezed away: m (R, n_max),
    # ghosts (R, g_max), rng (R,) keys | (R, n_max) LFSR, consts rows (…).

    def _exchange_block(self, m, macc, S, consts):
        """Publish boundary states, all-gather, gather this device's ghosts."""
        R = self.replicas
        bnd_slots = consts["bnd_slots"]                       # (b_pad,)
        if self.mode == "cmft":
            vals = (macc / jnp.float32(S))[:, bnd_slots]      # (R, b_pad)
            pool = jax.lax.all_gather(vals, self.axis, tiled=True)
        elif self.bitpack:
            bnd = m[:, bnd_slots]                             # (R, b_pad)
            packed = pack_pm1(bnd)                            # (R, b_pad/8)
            pool_p = jax.lax.all_gather(packed, self.axis, tiled=True)
            pool = unpack_pm1(pool_p, self.b_pad).astype(jnp.float32)
        else:
            bnd = m[:, bnd_slots]
            pool = jax.lax.all_gather(bnd, self.axis,
                                      tiled=True).astype(jnp.float32)
        # pool (K*R, b_pad) device-order-major -> (R, K*b_pad) per replica
        pool = pool.reshape(self.p.K, R, self.b_pad)
        pool = pool.transpose(1, 0, 2).reshape(R, -1)
        return pool[:, consts["ghost_src_pool"]]              # (R, g_max)

    def _phase_block(self, c, m, ghosts, rng, beta, consts):
        slots, mask = consts["color_slots"][c], consts["color_mask"][c]  # (nc,)
        mext = jnp.concatenate([m.astype(jnp.float32), ghosts], axis=1)
        idx_c = consts["local_idx"][slots]                    # (nc, D)
        w_c = consts["local_w"][slots]
        h_c = consts["local_h"][slots]
        nbr = jnp.take(mext, idx_c, axis=1)                   # (R, nc, D)
        field = h_c + (w_c * nbr).sum(axis=-1)                # (R, nc)
        if self.rng_kind == "philox":
            ks = jax.vmap(jax.random.split)(rng)              # (R, 2) keys
            rng = ks[:, 0]
            nc = field.shape[1]
            r = jax.vmap(lambda k: jax.random.uniform(
                k, (nc,), minval=-1.0, maxval=1.0))(ks[:, 1])
        else:
            s = rng[:, slots]
            s = lfsr_next(s)
            r = lfsr_uniform(s)
            rng = rng.at[:, slots].set(s)
        act = quantize(beta * field, self.fmt)
        old = m[:, slots]
        new = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
        new = jnp.where(mask, new, old)
        flips = (new != old).sum(axis=1).astype(jnp.int32)    # (R,)
        m = m.at[:, slots].set(new)
        return m, rng, flips

    def _iteration_block(self, m, ghosts, macc, rng, flips, betas_S, sync, consts):
        S = betas_S.shape[0]

        def body(carry, beta):
            m, ghosts, macc, rng, flips = carry
            for c in range(len(consts["color_slots"])):
                if sync == "phase":
                    ghosts = self._exchange_block(m, macc, 1, consts)
                m, rng, f = self._phase_block(c, m, ghosts, rng, beta, consts)
                flips = flips + f
            macc = macc + m.astype(jnp.float32)
            return (m, ghosts, macc, rng, flips), None

        (m, ghosts, macc, rng, flips), _ = jax.lax.scan(
            body, (m, ghosts, macc, rng, flips), betas_S)
        if sync not in ("phase", None):
            ghosts = self._exchange_block(m, macc, S, consts)
        macc = jnp.zeros_like(macc)
        return m, ghosts, macc, rng, flips

    # -- runners --------------------------------------------------------------------

    def _run_chunk(self, iters: int, S: int, sync: SyncSpec):
        key = (iters, S, sync)
        if key in self._chunk_cache:
            return self._chunk_cache[key]

        spec_m = P(self.axis)
        rng_spec = P(self.axis)
        cspec = dict(
            local_idx=spec_m, local_w=spec_m, local_h=spec_m,
            color_slots=tuple(spec_m for _ in self.p.color_slots),
            color_mask=tuple(spec_m for _ in self.p.color_mask),
            bnd_slots=spec_m, ghost_src_pool=spec_m,
        )

        def block(m, ghosts, macc, rng, flips_in, betas, consts):
            # squeeze the device-local partition dim from state and consts
            m, ghosts, macc, rng = m[0], ghosts[0], macc[0], rng[0]
            consts = jax.tree.map(lambda x: x[0], consts)
            local = jnp.zeros_like(flips_in)

            def it(carry, b):
                m, ghosts, macc, rng, fl = carry
                out = self._iteration_block(m, ghosts, macc, rng, fl, b,
                                            sync, consts)
                return out, None
            (m, ghosts, macc, rng, local), _ = jax.lax.scan(
                it, (m, ghosts, macc, rng, local), betas)
            flips = flips_in + jax.lax.psum(local, self.axis)
            return m[None], ghosts[None], macc[None], rng[None], flips

        smapped = shard_map(
            block, mesh=self.mesh,
            in_specs=(spec_m, spec_m, spec_m, rng_spec, P(), P(), cspec),
            out_specs=(spec_m, spec_m, spec_m, rng_spec, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: DSIMState, betas, consts):
            m, ghosts, macc, rng, flips = smapped(
                state.m, state.ghosts, state.macc, state.rng, state.flips,
                betas, consts)
            return DSIMState(m=m, ghosts=ghosts, macc=macc, rng=rng,
                             sweep=state.sweep + betas.shape[0] * betas.shape[1],
                             flips=flips)

        self._chunk_cache[key] = run
        return run

    def run_recorded_full(self, state: DSIMState, schedule,
                          record_points: Sequence[int], *,
                          cursor: bool = False,
                          sync_every: SyncSpec = 1):
        """Shared-driver runner; returns (state, RunRecord) — or, with
        ``cursor=True``, the resumable RecordedCursor."""
        sync = sync_every if sync_every in ("phase", None) else int(sync_every)

        def chunk(st, betas2d, iters, S):
            return self._run_chunk(iters, S, sync)(st, betas2d, self._consts)

        kw = dict(
            state=state, schedule=schedule, record_points=record_points,
            chunk_fn=chunk, record_fn=self.energy, sync_every=sync_every,
            flips_of=lambda st: st.flips,
            flips_per_sweep=self.p.n * self.replicas)
        if cursor:
            return RecordedCursor(**kw)
        return run_recorded_driver(**kw)

    def run_recorded(self, state: DSIMState, schedule,
                     record_points: Sequence[int], sync_every: SyncSpec = 1):
        """Run to each record point; returns (state, (times, energies))."""
        return self.run_recorded_full(state, schedule, record_points,
                                      sync_every=sync_every)

    # -- observables -------------------------------------------------------------------

    def global_spins(self, state: DSIMState) -> jnp.ndarray:
        """(R, N) global spins; squeezed to (N,) when replicas == 1."""
        p, R = self.p, self.replicas

        def one(m_r):                                     # (K, n_max)
            buf = jnp.ones((p.n + 1,), dtype=jnp.int8)
            buf = buf.at[p.global_ids.reshape(-1)].set(m_r.reshape(-1))
            return buf[: p.n]

        spins = jax.vmap(one)(state.m.transpose(1, 0, 2))
        return spins[0] if R == 1 else spins

    def _energy_impl(self, state: DSIMState) -> jnp.ndarray:
        spins = self.global_spins(state)
        if self.replicas == 1:
            return direct_energy(self.p.graph, spins)
        return jax.vmap(lambda m: direct_energy(self.p.graph, m))(spins)

    def energy(self, state: DSIMState) -> jnp.ndarray:
        """(R,) true global energies (scalar when replicas == 1)."""
        return self._energy(state)

    # -- dry-run hook --------------------------------------------------------------------

    def lower_chunk(self, iters: int = 4, S: int = 4, sync: SyncSpec = 4):
        """Lower (not run) one sampling chunk — used by the launch dry-run."""
        run = self._run_chunk(iters, S, sync)
        p, R = self.p, self.replicas

        def sds(x, shard):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=shard)

        rng_t = jax.random.split(jax.random.PRNGKey(0), p.K * R).reshape(p.K, R) \
            if self.rng_kind == "philox" else \
            jnp.zeros((p.K, R, p.n_max), jnp.uint32)
        zero = jnp.zeros((), jnp.int32)
        st = DSIMState(
            m=jax.ShapeDtypeStruct((p.K, R, p.n_max), jnp.int8, sharding=self._shard),
            ghosts=jax.ShapeDtypeStruct((p.K, R, p.g_max), jnp.float32, sharding=self._shard),
            macc=jax.ShapeDtypeStruct((p.K, R, p.n_max), jnp.float32, sharding=self._shard),
            rng=sds(rng_t, self._shard),
            sweep=sds(zero, self._repl),
            flips=sds(jnp.zeros((R,), jnp.int32), self._repl),
        )
        betas = jax.ShapeDtypeStruct((iters, S), jnp.float32, sharding=self._repl)
        consts = jax.tree.map(lambda x: sds(x, self._shard), self._consts)
        return run.lower(st, betas, consts)
