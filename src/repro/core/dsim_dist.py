"""Distributed (shard_map) backend of the DSIM.

Each mesh device hosts one partition: local spins, shadow weights, and ghost
slots live device-local; the *only* collective during sampling is the
boundary-state exchange — an all-gather of 1-bit-packed boundary spins, every
``sync_every`` sweeps.  This is the TPU-native realization of the paper's
"devices exchange nothing but 1-bit boundary states".

Semantics are identical to the stacked backend in :mod:`repro.core.dsim`
(verified in tests with a multi-device subprocess); the same
:class:`PartitionedProblem` feeds both.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .dsim import PartitionedProblem, DSIMState
from .pbit import FixedPoint, quantize, lfsr_init, lfsr_next, lfsr_uniform
from .packing import pack_pm1, unpack_pm1, pad_to_multiple
from .energy import energy as direct_energy
from .gibbs import chunk_plan

__all__ = ["DistDSIMEngine"]

SyncSpec = Union[int, str, None]


class DistDSIMEngine:
    """One partition per device along ``axis`` of ``mesh`` (K = axis size)."""

    def __init__(self, prob: PartitionedProblem, mesh: Mesh,
                 axis: Union[str, tuple] = "data",
                 rng: str = "philox", fmt: Optional[FixedPoint] = None,
                 mode: str = "dsim", bitpack: bool = True):
        axis_tuple = (axis,) if isinstance(axis, str) else tuple(axis)
        ndev = int(np.prod([mesh.shape[a] for a in axis_tuple]))
        if ndev != prob.K:
            raise ValueError(f"mesh axis size {ndev} != K={prob.K}")
        if mode not in ("dsim", "cmft"):
            raise ValueError(mode)
        self.p = prob
        self.mesh = mesh
        self.axis = axis_tuple if len(axis_tuple) > 1 else axis_tuple[0]
        self.rng_kind = rng
        self.fmt = fmt
        self.mode = mode
        # bit-packing needs b_max % 8 == 0; re-pad the packed pool coords
        self.b_pad = pad_to_multiple(prob.b_max, 8)
        self.bitpack = bitpack and mode == "dsim"
        self._shard = NamedSharding(mesh, P(self.axis))
        self._repl = NamedSharding(mesh, P())
        self._chunk_cache = {}

        bs = np.asarray(prob.bnd_slots)
        pad = np.zeros((prob.K, self.b_pad - prob.b_max), dtype=bs.dtype)
        self._bnd_slots = jnp.asarray(np.concatenate([bs, pad], axis=1))
        gsp = np.asarray(prob.ghost_src_packed)
        gk, gc = gsp // prob.b_max, gsp % prob.b_max
        self._ghost_src_pool = jnp.asarray((gk * self.b_pad + gc).astype(np.int32))

        self._consts = dict(
            local_idx=prob.local_idx, local_w=prob.local_w, local_h=prob.local_h,
            color_slots=prob.color_slots, color_mask=prob.color_mask,
            bnd_slots=self._bnd_slots, ghost_src_pool=self._ghost_src_pool,
        )

    # -- state ------------------------------------------------------------------

    def init_state(self, seed: int = 0) -> DSIMState:
        p = self.p
        key = jax.random.PRNGKey(seed)
        key, sub = jax.random.split(key)
        m = jnp.where(jax.random.bernoulli(sub, 0.5, (p.K, p.n_max)), 1, -1)
        m = m.astype(jnp.int8)
        if self.rng_kind == "philox":
            rng = jax.random.split(key, p.K)        # (K,) typed keys
        else:
            rng = lfsr_init(p.K * p.n_max, seed).reshape(p.K, p.n_max)
        ghosts = self._exchange_host(m)
        zero = jnp.zeros((), dtype=jnp.int32)
        st = DSIMState(m=m, ghosts=ghosts,
                       macc=jnp.zeros((p.K, p.n_max), jnp.float32),
                       rng=rng, sweep=zero, flips=zero)
        return self.shard_state(st)

    def shard_state(self, st: DSIMState) -> DSIMState:
        put = lambda x: jax.device_put(x, self._shard)
        return DSIMState(m=put(st.m), ghosts=put(st.ghosts), macc=put(st.macc),
                         rng=put(st.rng),
                         sweep=jax.device_put(st.sweep, self._repl),
                         flips=jax.device_put(st.flips, self._repl))

    def _exchange_host(self, m) -> jnp.ndarray:
        flat = m.reshape(-1).astype(jnp.float32)
        return flat[self.p.ghost_src]

    # -- device-local block functions (run inside shard_map) -----------------------

    def _exchange_block(self, m, macc, S, consts):
        """Publish boundary states, all-gather, gather this device's ghosts."""
        if self.mode == "cmft":
            vals = jnp.take_along_axis(macc / jnp.float32(S),
                                       consts["bnd_slots"], axis=1)
            pool = jax.lax.all_gather(vals[0], self.axis, tiled=True)
        elif self.bitpack:
            bnd = jnp.take_along_axis(m, consts["bnd_slots"], axis=1)   # (1, b_pad)
            packed = pack_pm1(bnd[0])
            pool_p = jax.lax.all_gather(packed, self.axis, tiled=True)
            pool = unpack_pm1(pool_p, self.p.K * self.b_pad).astype(jnp.float32)
        else:
            bnd = jnp.take_along_axis(m, consts["bnd_slots"], axis=1)
            pool = jax.lax.all_gather(bnd[0], self.axis,
                                      tiled=True).astype(jnp.float32)
        pool = pool.reshape(-1)
        return pool[consts["ghost_src_pool"]]                 # (1, g_max)

    def _phase_block(self, c, m, ghosts, rng, beta, consts):
        slots, mask = consts["color_slots"][c], consts["color_mask"][c]
        mext = jnp.concatenate([m.astype(jnp.float32), ghosts], axis=1)
        idx_c = jnp.take_along_axis(consts["local_idx"], slots[:, :, None], axis=1)
        w_c = jnp.take_along_axis(consts["local_w"], slots[:, :, None], axis=1)
        h_c = jnp.take_along_axis(consts["local_h"], slots, axis=1)
        nbr = jax.vmap(lambda row, ii: row[ii])(mext, idx_c)
        field = h_c + (w_c * nbr).sum(axis=-1)
        if self.rng_kind == "philox":
            k0, sub = jax.random.split(rng[0])
            rng = rng.at[0].set(k0)
            r = jax.random.uniform(sub, field.shape, minval=-1.0, maxval=1.0)
        else:
            s = jnp.take_along_axis(rng, slots, axis=1)
            s = lfsr_next(s)
            r = lfsr_uniform(s)
            rng = rng.at[jnp.zeros_like(slots), slots].set(s)
        act = quantize(beta * field, self.fmt)
        old = jnp.take_along_axis(m, slots, axis=1)
        new = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
        new = jnp.where(mask, new, old)
        flips = (new != old).sum().astype(jnp.int32)
        m = m.at[jnp.zeros_like(slots), slots].set(new)
        return m, rng, flips

    def _iteration_block(self, m, ghosts, macc, rng, flips, betas_S, sync, consts):
        S = betas_S.shape[0]

        def body(carry, beta):
            m, ghosts, macc, rng, flips = carry
            for c in range(len(consts["color_slots"])):
                if sync == "phase":
                    ghosts = self._exchange_block(m, macc, 1, consts)
                m, rng, f = self._phase_block(c, m, ghosts, rng, beta, consts)
                flips = flips + f
            macc = macc + m.astype(jnp.float32)
            return (m, ghosts, macc, rng, flips), None

        (m, ghosts, macc, rng, flips), _ = jax.lax.scan(
            body, (m, ghosts, macc, rng, flips), betas_S)
        if sync not in ("phase", None):
            ghosts = self._exchange_block(m, macc, S, consts)
        macc = jnp.zeros_like(macc)
        return m, ghosts, macc, rng, flips

    # -- runners --------------------------------------------------------------------

    def _run_chunk(self, iters: int, S: int, sync: SyncSpec):
        key = (iters, S, sync)
        if key in self._chunk_cache:
            return self._chunk_cache[key]

        spec_m = P(self.axis)
        rng_spec = P(self.axis)
        cspec = dict(
            local_idx=spec_m, local_w=spec_m, local_h=spec_m,
            color_slots=tuple(spec_m for _ in self.p.color_slots),
            color_mask=tuple(spec_m for _ in self.p.color_mask),
            bnd_slots=spec_m, ghost_src_pool=spec_m,
        )

        def block(m, ghosts, macc, rng, flips_in, betas, consts):
            local = jnp.zeros((), jnp.int32)

            def it(carry, b):
                m, ghosts, macc, rng, fl = carry
                out = self._iteration_block(m, ghosts, macc, rng, fl, b,
                                            sync, consts)
                return out, None
            (m, ghosts, macc, rng, local), _ = jax.lax.scan(
                it, (m, ghosts, macc, rng, local), betas)
            flips = flips_in + jax.lax.psum(local, self.axis)
            return m, ghosts, macc, rng, flips

        smapped = jax.shard_map(
            block, mesh=self.mesh,
            in_specs=(spec_m, spec_m, spec_m, rng_spec, P(), P(), cspec),
            out_specs=(spec_m, spec_m, spec_m, rng_spec, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: DSIMState, betas, consts):
            m, ghosts, macc, rng, flips = smapped(
                state.m, state.ghosts, state.macc, state.rng, state.flips,
                betas, consts)
            return DSIMState(m=m, ghosts=ghosts, macc=macc, rng=rng,
                             sweep=state.sweep + betas.shape[0] * betas.shape[1],
                             flips=flips)

        self._chunk_cache[key] = run
        return run

    def run_recorded(self, state: DSIMState, schedule,
                     record_points: Sequence[int], sync_every: SyncSpec = 1):
        S = 1 if sync_every in ("phase", None) else int(sync_every)
        sync = sync_every if sync_every in ("phase", None) else int(sync_every)
        pts = sorted(set(max(S, int(round(pp / S)) * S) for pp in record_points))
        betas = schedule.beta_array()
        if len(betas) < pts[-1]:
            raise ValueError("schedule shorter than last record point")
        out, times, pos = [], [], 0
        for c in chunk_plan([pp // S for pp in pts]):
            nsw = c * S
            bchunk = jnp.asarray(betas[pos:pos + nsw]).reshape(c, S)
            state = self._run_chunk(c, S, sync)(state, bchunk, self._consts)
            pos += nsw
            if pos in set(pts):
                out.append(self.energy(state))
                times.append(pos)
        return state, (np.asarray(times), jnp.stack(out))

    # -- observables -------------------------------------------------------------------

    def global_spins(self, state: DSIMState) -> jnp.ndarray:
        p = self.p
        buf = jnp.ones((p.n + 1,), dtype=jnp.int8)
        buf = buf.at[p.global_ids.reshape(-1)].set(state.m.reshape(-1))
        return buf[: p.n]

    def energy(self, state: DSIMState) -> jnp.ndarray:
        return direct_energy(self.p.graph, self.global_spins(state))

    # -- dry-run hook --------------------------------------------------------------------

    def lower_chunk(self, iters: int = 4, S: int = 4, sync: SyncSpec = 4):
        """Lower (not run) one sampling chunk — used by the launch dry-run."""
        run = self._run_chunk(iters, S, sync)
        p = self.p

        def sds(x, shard):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=shard)

        rng_t = jax.random.split(jax.random.PRNGKey(0), p.K) \
            if self.rng_kind == "philox" else \
            jnp.zeros((p.K, p.n_max), jnp.uint32)
        zero = jnp.zeros((), jnp.int32)
        st = DSIMState(
            m=jax.ShapeDtypeStruct((p.K, p.n_max), jnp.int8, sharding=self._shard),
            ghosts=jax.ShapeDtypeStruct((p.K, p.g_max), jnp.float32, sharding=self._shard),
            macc=jax.ShapeDtypeStruct((p.K, p.n_max), jnp.float32, sharding=self._shard),
            rng=sds(rng_t, self._shard),
            sweep=sds(zero, self._repl),
            flips=sds(zero, self._repl),
        )
        betas = jax.ShapeDtypeStruct((iters, S), jnp.float32, sharding=self._repl)
        consts = jax.tree.map(lambda x: sds(x, self._shard), self._consts)
        return run.lower(st, betas, consts)
