"""Monolithic chromatic Gibbs sampler — the paper's unpartitioned baseline.

One sweep (one MCS) updates all N p-bits once, color group by color group.
The energy is tracked incrementally: within a color group the members are
mutually non-adjacent, so per-spin deltas  -(m_new - m_old) * field  sum
exactly; tests check against the direct energy.

``rng='philox'`` (jax.random, the paper's GPU baseline RNG) or ``rng='lfsr'``
(vectorized xorshift32, the paper's hardware RNG).

Replicas: ``init_state(..., replicas=R)`` returns a batched state whose
leaves carry a leading R axis — R independent chains (independent RNG
streams via spawned seeds) advanced together by one vmapped sweep, the
software analogue of the paper running many anneals on one machine.
Unbatched states remain first-class and bitwise-stable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import IsingGraph
from .coloring import Coloring
from .pbit import FixedPoint, pbit_update, lfsr_init, lfsr_next, lfsr_uniform
from .energy import energy as direct_energy
from repro.engines.base import (RecordedCursor, run_recorded_driver, spawn_seeds,
                                stack_states)
from repro.engines.base import chunk_plan  # noqa: F401  (legacy import path)

__all__ = ["GibbsEngine", "GibbsState", "chunk_plan", "color_fields"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GibbsState:
    m: jnp.ndarray          # (N,) int8 spins — or (R, N) batched
    rng: jnp.ndarray        # philox: PRNG key; lfsr: (N,) uint32 states
    E: jnp.ndarray          # scalar f32, incrementally tracked energy
    sweep: jnp.ndarray      # scalar int32
    flips: jnp.ndarray      # scalar int32 modular odometer; the recording
                            # driver accumulates the exact (>= int64) total
                            # host-side from per-chunk deltas


def color_fields(m: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray,
                 h: jnp.ndarray) -> jnp.ndarray:
    """Local fields of one color group.

    m (..., N) spins; idx/w (nc, D) the group's ELL rows; h (nc,).
    Returns (..., nc).  Shared by the Gibbs engine and APT-ICM so both ride
    the same gather/accumulate path (and the same batching semantics).
    """
    nbr = jnp.take(m, idx, axis=-1).astype(w.dtype)      # (..., nc, D)
    return h + (w * nbr).sum(axis=-1)


class GibbsEngine:
    """Colored Gibbs sampler over an ELL Ising graph."""

    def __init__(self, g: IsingGraph, coloring: Coloring,
                 rng: str = "philox", fmt: Optional[FixedPoint] = None):
        if rng not in ("philox", "lfsr"):
            raise ValueError(f"unknown rng {rng!r}")
        self.g = g
        self.coloring = coloring
        self.rng_kind = rng
        self.fmt = fmt
        self.n = g.n
        # per-color static gathers
        self._nodes = [jnp.asarray(grp) for grp in coloring.groups]
        self._idx = [jnp.take(g.idx, grp, axis=0) for grp in self._nodes]
        self._w = [jnp.take(g.w, grp, axis=0) for grp in self._nodes]
        self._h = [jnp.take(g.h, grp) for grp in self._nodes]
        self._run_chunk_cache = {}

    # -- state ---------------------------------------------------------------

    def init_state(self, seed: int = 0, m0: Optional[np.ndarray] = None,
                   replicas: Optional[int] = None,
                   seeds: Optional[Sequence[int]] = None) -> GibbsState:
        """Fresh state; ``replicas=R`` stacks R independent chains (leading
        replica axis, per-replica RNG streams from spawned seeds).
        ``seeds=[...]`` instead gives every chain its own explicit seed —
        the packed-batch path, where replica r's trajectory depends only on
        seeds[r] (co-packed tenants never perturb each other)."""
        if seeds is not None:
            return stack_states([self.init_state(int(s), m0=m0)
                                 for s in seeds])
        if replicas is not None:
            return stack_states([self.init_state(s, m0=m0)
                                 for s in spawn_seeds(seed, replicas)])
        key = jax.random.PRNGKey(seed)
        if m0 is None:
            key, sub = jax.random.split(key)
            m = jax.random.bernoulli(sub, 0.5, (self.n,))
            m = jnp.where(m, 1, -1).astype(jnp.int8)
        else:
            m = jnp.asarray(m0, dtype=jnp.int8)
        rng = key if self.rng_kind == "philox" else lfsr_init(self.n, seed)
        E = direct_energy(self.g, m)
        zero = jnp.zeros((), dtype=jnp.int32)
        return GibbsState(m=m, rng=rng, E=E, sweep=zero, flips=zero)

    @staticmethod
    def is_batched(state: GibbsState) -> bool:
        return state.m.ndim == 2

    # -- single sweep ---------------------------------------------------------

    def _phase(self, c: int, m, rng, beta):
        """Update color group c; returns (m, rng, dE, flips)."""
        nodes, idx, w, h = self._nodes[c], self._idx[c], self._w[c], self._h[c]
        field = color_fields(m, idx, w, h)
        if self.rng_kind == "philox":
            rng, sub = jax.random.split(rng)
            r = jax.random.uniform(sub, field.shape, minval=-1.0, maxval=1.0)
        else:
            s = jnp.take(rng, nodes)
            s = lfsr_next(s)
            r = lfsr_uniform(s)
            rng = rng.at[nodes].set(s)
        old = jnp.take(m, nodes)
        new = pbit_update(field, beta, r, self.fmt)
        dE = -((new - old).astype(jnp.float32) * field).sum()
        flips = (new != old).sum()
        m = m.at[nodes].set(new)
        return m, rng, dE, flips

    def sweep(self, state: GibbsState, beta) -> GibbsState:
        m, rng = state.m, state.rng
        E = state.E
        # flip odometer arithmetic is uint32-modular (contract rule IR-E);
        # the int32 state field is just the pytree/snapshot dtype view
        fl_u = jax.lax.bitcast_convert_type(state.flips, jnp.uint32)
        for c in range(len(self._nodes)):
            m, rng, dE, f = self._phase(c, m, rng, beta)
            E = E + dE
            fl_u = fl_u + f.astype(jnp.uint32)
        flips = jax.lax.bitcast_convert_type(fl_u, jnp.int32)
        return GibbsState(m=m, rng=rng, E=E, sweep=state.sweep + 1, flips=flips)

    def _sweep_maybe_batched(self, batched: bool, per_replica_beta: bool):
        if not batched:
            return self.sweep
        return jax.vmap(self.sweep, in_axes=(0, 0 if per_replica_beta else None))

    # -- runners ---------------------------------------------------------------

    @functools.partial(jax.jit, static_argnums=(0, 3))
    def _run_dense(self, state: GibbsState, betas: jnp.ndarray,
                   batched: bool = False):
        step = self._sweep_maybe_batched(batched, per_replica_beta=False)

        def body(st, beta):
            st2 = step(st, beta)
            return st2, (st2.E, st2.flips - st.flips)

        return jax.lax.scan(body, state, betas)

    def run_dense(self, state: GibbsState, betas: np.ndarray):
        """Run len(betas) sweeps.

        Returns (state, (per-sweep energy trace, per-sweep flip counts));
        for batched states the traces carry a trailing replica axis.
        """
        return self._run_dense(state, jnp.asarray(betas, dtype=jnp.float32),
                               self.is_batched(state))

    def _run_chunk(self, n: int, batched: bool = False,
                   per_replica_beta: bool = False):
        key = (n, batched, per_replica_beta)
        if key not in self._run_chunk_cache:
            step = self._sweep_maybe_batched(batched, per_replica_beta)

            @jax.jit
            def f(state, betas):
                def body(st, beta):
                    return step(st, beta), None
                st, _ = jax.lax.scan(body, state, betas)
                return st
            self._run_chunk_cache[key] = f
        return self._run_chunk_cache[key]

    def run_recorded_full(self, state: GibbsState, schedule,
                          record_points: Sequence[int], sync_every=1,
                          betas_R: Optional[np.ndarray] = None,
                          cursor: bool = False):
        """Shared-driver runner; returns (state, RunRecord).

        ``sync_every`` is accepted (and ignored — the monolithic engine has
        no boundaries) so every engine exposes one signature.
        ``betas_R`` (total_sweeps, R) optionally gives each replica its own
        staircase (replica-aware annealing).  ``cursor=True`` returns the
        resumable :class:`~repro.engines.base.RecordedCursor` instead of
        driving the run to completion."""
        batched = self.is_batched(state)
        per_rep = betas_R is not None
        if per_rep and not batched:
            raise ValueError("per-replica betas need a batched state")
        from .annealing import ArraySchedule
        sched = schedule if not per_rep else \
            ArraySchedule(np.asarray(betas_R, np.float32))

        def chunk(st, betas2d, iters, S):
            flat = betas2d.reshape((iters * S,) + betas2d.shape[2:])
            return self._run_chunk(iters * S, batched, per_rep)(st, flat)

        R = state.m.shape[0] if batched else 1
        kw = dict(
            state=state, schedule=sched, record_points=record_points,
            chunk_fn=chunk, record_fn=lambda st: st.E, sync_every=1,
            flips_of=lambda st: st.flips, flips_per_sweep=self.n * R)
        if cursor:
            return RecordedCursor(**kw)
        return run_recorded_driver(**kw)

    def run_recorded(self, state: GibbsState, schedule,
                     record_points: Sequence[int]):
        """Run to each record point (power-of-2 chunking); returns
        (state, E at points) — the legacy signature."""
        state, rec = self.run_recorded_full(state, schedule, record_points)
        return state, rec.energies

    # -- checks ---------------------------------------------------------------

    def direct_energy(self, state: GibbsState) -> jnp.ndarray:
        if self.is_batched(state):
            return jax.vmap(lambda m: direct_energy(self.g, m))(state.m)
        return direct_energy(self.g, state.m)

