"""Monolithic chromatic Gibbs sampler — the paper's unpartitioned baseline.

One sweep (one MCS) updates all N p-bits once, color group by color group.
The energy is tracked incrementally: within a color group the members are
mutually non-adjacent, so per-spin deltas  -(m_new - m_old) * field  sum
exactly; tests check against the direct energy.

``rng='philox'`` (jax.random, the paper's GPU baseline RNG) or ``rng='lfsr'``
(vectorized xorshift32, the paper's hardware RNG).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import IsingGraph
from .coloring import Coloring
from .pbit import FixedPoint, pbit_update, lfsr_init, lfsr_next, lfsr_uniform
from .energy import energy as direct_energy

__all__ = ["GibbsEngine", "GibbsState", "chunk_plan"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GibbsState:
    m: jnp.ndarray          # (N,) int8 spins
    rng: jnp.ndarray        # philox: PRNG key; lfsr: (N,) uint32 states
    E: jnp.ndarray          # scalar f32, incrementally tracked energy
    sweep: jnp.ndarray      # scalar int32
    flips: jnp.ndarray      # scalar int32 (wraps on very long runs; use the
                            # per-sweep trace from run_dense for exact totals)


def chunk_plan(points: Sequence[int]) -> List[Tuple[int, int]]:
    """Decompose gaps between record points into power-of-two chunks.

    Returns [(chunk_len, times)...] flattened as a list of (len, point?) —
    concretely a list of chunk lengths whose cumsum passes through every
    point, using only power-of-two lengths so at most log2(max_gap) distinct
    jit signatures are compiled.
    """
    plan = []
    prev = 0
    for p in points:
        gap = int(p) - prev
        if gap < 0:
            raise ValueError("record points must be nondecreasing")
        while gap > 0:
            c = 1 << (gap.bit_length() - 1)
            plan.append(c)
            gap -= c
        prev = int(p)
    return plan


class GibbsEngine:
    """Colored Gibbs sampler over an ELL Ising graph."""

    def __init__(self, g: IsingGraph, coloring: Coloring,
                 rng: str = "philox", fmt: Optional[FixedPoint] = None):
        if rng not in ("philox", "lfsr"):
            raise ValueError(f"unknown rng {rng!r}")
        self.g = g
        self.coloring = coloring
        self.rng_kind = rng
        self.fmt = fmt
        self.n = g.n
        # per-color static gathers
        self._nodes = [jnp.asarray(grp) for grp in coloring.groups]
        self._idx = [jnp.take(g.idx, grp, axis=0) for grp in self._nodes]
        self._w = [jnp.take(g.w, grp, axis=0) for grp in self._nodes]
        self._h = [jnp.take(g.h, grp) for grp in self._nodes]
        self._run_chunk_cache = {}

    # -- state ---------------------------------------------------------------

    def init_state(self, seed: int = 0, m0: Optional[np.ndarray] = None) -> GibbsState:
        key = jax.random.PRNGKey(seed)
        if m0 is None:
            key, sub = jax.random.split(key)
            m = jax.random.bernoulli(sub, 0.5, (self.n,))
            m = jnp.where(m, 1, -1).astype(jnp.int8)
        else:
            m = jnp.asarray(m0, dtype=jnp.int8)
        rng = key if self.rng_kind == "philox" else lfsr_init(self.n, seed)
        E = direct_energy(self.g, m)
        zero = jnp.zeros((), dtype=jnp.int32)
        return GibbsState(m=m, rng=rng, E=E, sweep=zero, flips=zero)

    # -- single sweep ---------------------------------------------------------

    def _phase(self, c: int, m, rng, beta):
        """Update color group c; returns (m, rng, dE, flips)."""
        nodes, idx, w, h = self._nodes[c], self._idx[c], self._w[c], self._h[c]
        nbr = jnp.take(m, idx, axis=0).astype(w.dtype)
        field = h + (w * nbr).sum(axis=-1)
        if self.rng_kind == "philox":
            rng, sub = jax.random.split(rng)
            r = jax.random.uniform(sub, field.shape, minval=-1.0, maxval=1.0)
        else:
            s = jnp.take(rng, nodes)
            s = lfsr_next(s)
            r = lfsr_uniform(s)
            rng = rng.at[nodes].set(s)
        old = jnp.take(m, nodes)
        new = pbit_update(field, beta, r, self.fmt)
        dE = -((new - old).astype(jnp.float32) * field).sum()
        flips = (new != old).sum()
        m = m.at[nodes].set(new)
        return m, rng, dE, flips

    def sweep(self, state: GibbsState, beta) -> GibbsState:
        m, rng = state.m, state.rng
        E, flips = state.E, state.flips
        for c in range(len(self._nodes)):
            m, rng, dE, f = self._phase(c, m, rng, beta)
            E = E + dE
            flips = flips + f.astype(jnp.int32)
        return GibbsState(m=m, rng=rng, E=E, sweep=state.sweep + 1, flips=flips)

    # -- runners ---------------------------------------------------------------

    @functools.partial(jax.jit, static_argnums=0)
    def _run_dense(self, state: GibbsState, betas: jnp.ndarray):
        def body(st, beta):
            st2 = self.sweep(st, beta)
            return st2, (st2.E, st2.flips - st.flips)

        return jax.lax.scan(body, state, betas)

    def run_dense(self, state: GibbsState, betas: np.ndarray):
        """Run len(betas) sweeps.

        Returns (state, (per-sweep energy trace, per-sweep flip counts)).
        """
        return self._run_dense(state, jnp.asarray(betas, dtype=jnp.float32))

    def _run_chunk(self, n: int):
        if n not in self._run_chunk_cache:
            @jax.jit
            def f(state, betas):
                def body(st, beta):
                    return self.sweep(st, beta), None
                st, _ = jax.lax.scan(body, state, betas)
                return st
            self._run_chunk_cache[n] = f
        return self._run_chunk_cache[n]

    def run_recorded(self, state: GibbsState, schedule, record_points: Sequence[int]):
        """Run to each record point (power-of-2 chunking); returns (state, E at points)."""
        betas = schedule.beta_array()
        out = []
        pos = 0
        plan = chunk_plan(record_points)
        targets = set(int(p) for p in record_points)
        for c in plan:
            state = self._run_chunk(c)(state, jnp.asarray(betas[pos:pos + c]))
            pos += c
            if pos in targets:
                out.append(state.E)
        return state, jnp.stack(out)

    # -- checks ---------------------------------------------------------------

    def direct_energy(self, state: GibbsState) -> jnp.ndarray:
        return direct_energy(self.g, state.m)
