"""Max-Cut problems (the Gset / G81 family of the paper's Sec. S9).

Max-Cut on weights w maps to the Ising model J = -w (minimizing
E = -sum J_ij m_i m_j maximizes the cut).  cut(m) = (W_tot - sum w m m)/2.
The true G81 file is not bundled offline; :func:`gset_like_toroidal`
generates instances of the same family (toroidal grid, +-1 weights) and
:func:`parse_gset` reads standard Gset files when available.
"""

from __future__ import annotations

import io
from typing import Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.graph import IsingGraph, from_edges, toroidal_grid, edges_from_ell

__all__ = ["parse_gset", "gset_like_toroidal", "maxcut_to_ising", "cut_of",
           "spins_to_hex", "hex_to_spins"]


def parse_gset(text_or_path: Union[str, io.TextIOBase]) -> IsingGraph:
    """Parse the Gset format: 'n m' header then 'i j w' (1-based) lines."""
    if isinstance(text_or_path, str) and "\n" not in text_or_path:
        with open(text_or_path) as f:
            text = f.read()
    elif isinstance(text_or_path, str):
        text = text_or_path
    else:
        text = text_or_path.read()
    lines = [l for l in text.strip().splitlines() if l.strip()]
    n, m = map(int, lines[0].split()[:2])
    ei, ej, ew = [], [], []
    for l in lines[1:m + 1]:
        a, b, w = l.split()[:3]
        ei.append(int(a) - 1)
        ej.append(int(b) - 1)
        ew.append(float(w))
    return from_edges(n, np.asarray(ei), np.asarray(ej),
                      np.asarray(ew, dtype=np.float32), meta={"kind": "gset"})


def gset_like_toroidal(rows: int = 100, cols: int = 200, seed: int = 0) -> IsingGraph:
    """A G81-shaped instance: 100x200 toroidal grid, +-1 weights (20k nodes)."""
    return toroidal_grid(rows, cols, seed=seed, weights="pm1")


def maxcut_to_ising(g: IsingGraph) -> IsingGraph:
    """J = -w; biases zero."""
    return IsingGraph(idx=g.idx, w=-g.w, h=jnp.zeros_like(g.h),
                      meta={**g.meta, "mapped": "maxcut"})


def cut_of(g_orig: IsingGraph, m) -> float:
    """Cut value of spins m on the ORIGINAL (unmapped) weighted graph."""
    mf = jnp.asarray(m).astype(g_orig.w.dtype)
    nbr = jnp.take(jnp.asarray(m), g_orig.idx, axis=0).astype(g_orig.w.dtype)
    disagree = (1.0 - mf[:, None] * nbr) * 0.5
    return float(0.5 * (g_orig.w * disagree).sum())


def spins_to_hex(m: np.ndarray) -> str:
    """The paper's verification encoding: {-1,+1} -> {0,1} bits -> hex."""
    bits = (np.asarray(m) > 0).astype(np.uint8)
    pad = (-len(bits)) % 4
    bits = np.concatenate([bits, np.zeros(pad, np.uint8)])
    nibbles = bits.reshape(-1, 4)
    vals = nibbles @ np.array([8, 4, 2, 1], np.uint8)
    return "".join(f"{v:X}" for v in vals)


def hex_to_spins(hx: str, n: int) -> np.ndarray:
    bits = []
    for ch in hx.strip():
        v = int(ch, 16)
        bits.extend([(v >> 3) & 1, (v >> 2) & 1, (v >> 1) & 1, v & 1])
    bits = np.asarray(bits[:n], dtype=np.int8)
    return (bits * 2 - 1).astype(np.int8)
