"""Planted Ising instances with ground states known by construction
(paper Sec. S11; frustrated-loop planting in the style of Hen et al.).

Construction: sample random simple cycles on a host graph; every cycle gets
ferromagnetic couplings (+1) except one antiferromagnetic (-1) edge.  Each
loop's minimum energy is -(len-2), achieved by the all-up state, so the sum
Hamiltonian has E_ground = -sum_l (len_l - 2), also achieved by all-up:
E(s) = sum_l E_l(s) >= sum_l min_s E_l = E(all-up).  A random gauge
sigma in {+-1}^N then hides the planted state: J_ij -> J_ij sigma_i sigma_j,
ground state sigma with the same energy.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.graph import IsingGraph, from_edges

__all__ = ["PlantedInstance", "plant_frustrated_loops"]


@dataclasses.dataclass(frozen=True)
class PlantedInstance:
    graph: IsingGraph
    ground_state: np.ndarray   # sigma, a ground state by construction
    ground_energy: float


def _random_cycle(adj: List[np.ndarray], rng, max_len: int) -> List[int]:
    """Random walk until it self-intersects; return the cycle found."""
    for _ in range(50):
        start = int(rng.integers(len(adj)))
        path = [start]
        seen = {start: 0}
        for _ in range(max_len):
            nbrs = adj[path[-1]]
            if len(nbrs) == 0:
                break
            nxt = int(nbrs[rng.integers(len(nbrs))])
            if len(path) > 1 and nxt == path[-2]:
                continue  # no immediate backtrack
            if nxt in seen:
                cyc = path[seen[nxt]:]
                if len(cyc) >= 3:
                    return cyc
                break
            seen[nxt] = len(path)
            path.append(nxt)
    return []


def plant_frustrated_loops(host: IsingGraph, n_loops: int,
                           max_len: int = 12, seed: int = 0) -> PlantedInstance:
    """Plant on the host graph's topology (its weights are ignored)."""
    rng = np.random.default_rng(seed)
    idx = np.asarray(host.idx)
    w = np.asarray(host.w)
    n = idx.shape[0]
    adj = [idx[i][w[i] != 0] for i in range(n)]

    Jmap = {}
    ground = 0.0
    loops = 0
    attempts = 0
    while loops < n_loops and attempts < 20 * n_loops:
        attempts += 1
        cyc = _random_cycle(adj, rng, max_len)
        if not cyc:
            continue
        L = len(cyc)
        afm = int(rng.integers(L))
        for t in range(L):
            a, b = cyc[t], cyc[(t + 1) % L]
            key = (min(a, b), max(a, b))
            Jmap[key] = Jmap.get(key, 0.0) + (-1.0 if t == afm else 1.0)
        ground += -(L - 2)
        loops += 1
    if loops == 0:
        raise RuntimeError("failed to sample any cycle on the host graph")

    keys = np.asarray(list(Jmap.keys()), dtype=np.int64).reshape(-1, 2)
    vals = np.asarray([Jmap[tuple(k)] for k in keys], dtype=np.float32)
    nz = vals != 0
    sigma = rng.choice(np.array([-1, 1], dtype=np.int8), size=n)
    gauged = vals[nz] * sigma[keys[nz, 0]] * sigma[keys[nz, 1]]
    g = from_edges(n, keys[nz, 0], keys[nz, 1], gauged,
                   meta={"kind": "planted", "loops": loops, "seed": seed})
    return PlantedInstance(graph=g, ground_state=sigma, ground_energy=ground)
