"""EA spin-glass instance sets and putative-ground-energy bookkeeping.

Paper Methods: exact grounds are unknown at scale; the putative ground of an
instance is the minimum energy observed across all platforms and timing
settings, established from reference runs at least 10x longer than the
analysis window (prevents artificial late-time bending of the power law).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import ea3d, IsingGraph
from repro.core.coloring import lattice3d_coloring
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import ea_schedule

__all__ = ["instance_set", "GroundStore", "establish_grounds"]


def instance_set(L: int, n_instances: int = 10, seed0: int = 100) -> List[IsingGraph]:
    """The paper's 10-disorder-instance protocol."""
    return [ea3d(L, seed=seed0 + i) for i in range(n_instances)]


class GroundStore:
    """JSON-backed map (L, seed) -> best known energy, min-merged on update."""

    def __init__(self, path: str):
        self.path = path
        self._d: Dict[str, float] = {}
        if os.path.exists(path):
            with open(path) as f:
                self._d = json.load(f)

    @staticmethod
    def key(L: int, seed: int) -> str:
        return f"ea3d_L{L}_s{seed}"

    def get(self, L: int, seed: int) -> Optional[float]:
        return self._d.get(self.key(L, seed))

    def update(self, L: int, seed: int, energy: float) -> float:
        k = self.key(L, seed)
        cur = self._d.get(k, float("inf"))
        if energy < cur:
            self._d[k] = float(energy)
            self._save()
        return self._d[k]

    def _save(self):
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._d, f, indent=0, sort_keys=True)
        os.replace(tmp, self.path)


def establish_grounds(graphs: List[IsingGraph], store: GroundStore,
                      sweeps: int, runs: int = 2, seed0: int = 0) -> List[float]:
    """Long annealing runs to (re)establish putative grounds; returns them."""
    out = []
    for g in graphs:
        L, seed = g.meta["L"], g.meta["seed"]
        eng = GibbsEngine(g, lattice3d_coloring(L))
        sch = ea_schedule(sweeps)
        best = store.get(L, seed)
        best = float("inf") if best is None else best
        for r in range(runs):
            st = eng.init_state(seed=seed0 + 7919 * r)
            st, (Etr, _) = eng.run_dense(st, sch.beta_array())
            best = min(best, float(np.asarray(Etr).min()))
        out.append(store.update(L, seed, best))
    return out
