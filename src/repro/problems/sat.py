"""Random 3SAT -> invertible-logic Ising encoding with copy-gate
sparsification (paper Sec. S12).

Each clause (l1 v l2 v l3) becomes an invertible OR gate chain:
  y = OR(l1, l2)   (one auxiliary p-bit per clause)
  OR(y, l3) clamped TRUE (output substituted as a constant).

The OR gate Hamiltonian (De Morgan dual of the standard invertible AND,
Camsari et al., PRX 7, 031014):  J_AB=-1, J_AC=2, J_BC=2, h=(-1,-1,+2);
ground states are exactly the rows of the OR truth table.

High-degree variables are split into copy chains (J_copy ferromagnetic) so
that graph degree stays bounded — the paper's copy-gate sparsification that
keeps the graph sparse and colorable.  Decoding takes the majority vote over
the copies of each variable (Fig. S14).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import IsingGraph, from_edges

__all__ = ["random_3sat", "SatEncoding", "encode_3sat", "decode_assignment",
           "count_satisfied"]


def random_3sat(n_vars: int, n_clauses: int, seed: int = 0) -> np.ndarray:
    """Uniform random 3SAT (CNFgen-style): (m, 3) signed 1-based literals."""
    rng = np.random.default_rng(seed)
    clauses = np.empty((n_clauses, 3), dtype=np.int64)
    for i in range(n_clauses):
        vs = rng.choice(n_vars, size=3, replace=False) + 1
        signs = rng.choice([-1, 1], size=3)
        clauses[i] = vs * signs
    return clauses


@dataclasses.dataclass(frozen=True)
class SatEncoding:
    graph: IsingGraph
    n_vars: int
    clauses: np.ndarray
    copies_of: List[np.ndarray]   # per variable: spin indices of its copies
    n_aux: int


def encode_3sat(clauses: np.ndarray, n_vars: int,
                max_fanout: int = 6, j_copy: float = 2.0) -> SatEncoding:
    """Build the sparse Ising graph for a 3SAT formula."""
    m = len(clauses)
    # fanout per variable = number of clause slots it occupies
    occ = np.zeros(n_vars, dtype=np.int64)
    for c in clauses:
        for lit in c:
            occ[abs(lit) - 1] += 1

    copies_of: List[np.ndarray] = []
    next_id = 0
    for v in range(n_vars):
        k = max(1, int(np.ceil(occ[v] / max_fanout)))
        copies_of.append(np.arange(next_id, next_id + k))
        next_id += k
    aux0 = next_id                      # clause aux spins start here
    n_spins = next_id + m

    J: Dict[Tuple[int, int], float] = {}
    h = np.zeros(n_spins, dtype=np.float64)

    def addJ(a: int, b: int, val: float):
        if a == b:
            raise ValueError("self coupling")
        key = (min(a, b), max(a, b))
        J[key] = J.get(key, 0.0) + val

    # copy chains (rings for k > 2 improve robustness of majority decoding)
    for v in range(n_vars):
        cps = copies_of[v]
        for i in range(len(cps) - 1):
            addJ(int(cps[i]), int(cps[i + 1]), j_copy)
        if len(cps) > 2:
            addJ(int(cps[0]), int(cps[-1]), j_copy)

    # round-robin slot assignment over copies
    slot_ptr = np.zeros(n_vars, dtype=np.int64)

    def spin_of(lit: int) -> Tuple[int, int]:
        v = abs(lit) - 1
        cps = copies_of[v]
        s = int(cps[slot_ptr[v] % len(cps)])
        slot_ptr[v] += 1
        return s, (1 if lit > 0 else -1)

    for ci, (l1, l2, l3) in enumerate(clauses):
        a, sa = spin_of(int(l1))
        b, sb = spin_of(int(l2))
        y = aux0 + ci
        # OR(a, b) = y   [J_AB=-1, J_AC=2, J_BC=2, h=(-1,-1,2)] with literal signs
        addJ(a, b, -1.0 * sa * sb)
        addJ(a, y, 2.0 * sa)
        addJ(b, y, 2.0 * sb)
        h[a] += -1.0 * sa
        h[b] += -1.0 * sb
        h[y] += 2.0
        # OR(y, l3) clamped TRUE: substitute C=+1 into the OR gate
        cthree, sc = spin_of(int(l3))
        addJ(y, cthree, -1.0 * sc)
        h[y] += -1.0 + 2.0
        h[cthree] += -1.0 * sc + 2.0 * sc

    keys = np.asarray(list(J.keys()), dtype=np.int64).reshape(-1, 2)
    vals = np.asarray([J[tuple(k)] for k in keys], dtype=np.float32)
    nz = vals != 0
    g = from_edges(n_spins, keys[nz, 0], keys[nz, 1], vals[nz],
                   h=h.astype(np.float32),
                   meta={"kind": "3sat", "n_vars": n_vars, "m": m})
    return SatEncoding(graph=g, n_vars=n_vars, clauses=np.asarray(clauses),
                       copies_of=copies_of, n_aux=m)


def decode_assignment(enc: SatEncoding, m_spins: np.ndarray) -> np.ndarray:
    """Majority vote over copies -> boolean assignment (+-1 per variable)."""
    m_spins = np.asarray(m_spins)
    out = np.empty(enc.n_vars, dtype=np.int8)
    for v in range(enc.n_vars):
        s = m_spins[enc.copies_of[v]].sum()
        out[v] = 1 if s >= 0 else -1
    return out


def count_satisfied(clauses: np.ndarray, assign_pm1: np.ndarray) -> int:
    """Number of satisfied clauses for a +-1 assignment (index = var - 1)."""
    lit_vals = np.sign(clauses) * assign_pm1[np.abs(clauses) - 1]
    return int((lit_vals > 0).any(axis=1).sum())
