"""Mamba-2 (state-space duality) block: chunked SSD for train/prefill and
the exact recurrent update for decode.  [arXiv:2405.21060]

Layout follows the reference implementation with n_groups = 1:
  in_proj -> [z (d_inner), x (d_inner), B (d_state), C (d_state), dt (heads)]
  causal conv1d(k=4) over the (x, B, C) channels
  SSD over heads: h' = exp(dt*A) h + dt * B outer x ;  y = C . h + D x
  gated RMSNorm(y * silu(z)) -> out_proj

The chunked SSD computes the same recurrence with matmuls (MXU-friendly):
intra-chunk quadratic attention-like term + inter-chunk state passing —
this is the paper's "state-space dual" form.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_dense, dense, init_rms, rms_norm

__all__ = ["init_mamba2", "mamba2_fwd", "Mamba2Cache", "init_mamba2_cache"]

D_CONV = 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Mamba2Cache:
    conv: jnp.ndarray    # (B, D_CONV-1, conv_channels) rolling conv window
    ssm: jnp.ndarray     # (B, heads, headdim, d_state)


def init_mamba2_cache(batch: int, d_inner: int, d_state: int, heads: int,
                      headdim: int, dtype=jnp.bfloat16) -> Mamba2Cache:
    conv_ch = d_inner + 2 * d_state
    return Mamba2Cache(
        conv=jnp.zeros((batch, D_CONV - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, heads, headdim, d_state), jnp.float32))


def init_mamba2(key, d_model: int, d_state: int, headdim: int = 64,
                expand: int = 2, dtype=jnp.float32):
    d_inner = expand * d_model
    heads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * d_state + heads
    rng = np.random.default_rng(42)
    dt = np.exp(rng.uniform(np.log(1e-3), np.log(0.1), heads))
    dt_bias = dt + np.log(-np.expm1(-dt))   # inverse softplus
    return {
        "in_proj": init_dense(ks[0], d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (D_CONV, conv_ch), jnp.float32)
                   * (1.0 / np.sqrt(D_CONV))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.asarray(np.log(rng.uniform(1, 16, heads)), jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "norm": init_rms(d_inner, dtype),
        "out_proj": init_dense(ks[4], d_inner, d_model, dtype,
                               scale=1.0 / np.sqrt(d_inner)),
    }


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x[..., k]."""
    T = x.shape[-1]
    x = jnp.repeat(x[..., None], T, axis=-1)
    mask = jnp.tril(jnp.ones((T, T), bool), -1)
    x = jnp.where(mask, x, 0)
    x_seg = jnp.cumsum(x, axis=-2)
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, x_seg, -jnp.inf)


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """x (b,l,h,p), dt (b,l,h) post-softplus, A (h,) negative, B/C (b,l,n).

    Returns y (b,l,h,p), final_state (b,h,p,n).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = x.shape[1]
    nc = L // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bv = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    dA = dtc * A                                       # (b,nc,c,h)
    dA_cs = jnp.cumsum(dA, axis=2)

    # 1. intra-chunk (diagonal block) output
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))   # (b,nc,h,c,c)
    scores = jnp.einsum("bzin,bzjn,bzhij,bzjh->bzhij", Cc, Bc, Lmat, dtc)
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", scores, xc)

    # 2. chunk-final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,nc,c,h)
    states = jnp.einsum("bzch,bzcn,bzchp->bzhpn",
                        decay_states * dtc, Bc, xc)      # (b,nc,h,p,n)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (b,nc,h)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                 # emit PRE-chunk state

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (b,nc,h,p,n)

    # 4. state -> output within chunk
    state_decay = jnp.exp(dA_cs)                          # (b,nc,c,h)
    y_off = jnp.einsum("bzcn,bzhpn,bzch->bzchp",
                       Cc, prev_states.astype(x.dtype), state_decay)
    y = (y_diag.reshape(b, L, h, p) + y_off.reshape(b, L, h, p))
    return y[:, :l], final


def mamba2_fwd(p, x, *, d_state: int, headdim: int = 64, expand: int = 2,
               chunk: int = 128, cache: Optional[Mamba2Cache] = None
               ) -> Tuple[jnp.ndarray, Optional[Mamba2Cache]]:
    """x (B, S, D) -> (y, new_cache).  cache given + S small => decode path
    (exact recurrence); otherwise chunked SSD."""
    Bsz, S, D = x.shape
    d_inner = expand * D
    heads = d_inner // headdim
    zxbcdt = dense(p["in_proj"], x)
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)      # (B,S,conv_ch)

    new_cache = None
    if cache is not None:
        full = jnp.concatenate([cache.conv.astype(conv_in.dtype), conv_in],
                               axis=1)                      # (B, S+3, ch)
        new_conv = full[:, -(D_CONV - 1):]
        conv = sum(full[:, i:i + S] * p["conv_w"].astype(conv_in.dtype)[i]
                   for i in range(D_CONV)) + p["conv_b"].astype(conv_in.dtype)
    else:
        padded = jnp.pad(conv_in, ((0, 0), (D_CONV - 1, 0), (0, 0)))
        conv = sum(padded[:, i:i + S] * p["conv_w"].astype(conv_in.dtype)[i]
                   for i in range(D_CONV)) + p["conv_b"].astype(conv_in.dtype)
    conv = jax.nn.silu(conv)
    xs, Bs, Cs = jnp.split(conv, [d_inner, d_inner + d_state], axis=-1)
    xs = xs.reshape(Bsz, S, heads, headdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,h)
    A = -jnp.exp(p["A_log"])                                      # (h,)

    if cache is not None and S == 1:
        # exact recurrent step
        st = cache.ssm                                            # (B,h,p,n)
        dA = jnp.exp(dt[:, 0] * A)                                # (B,h)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bs[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        st = st * dA[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cs[:, 0].astype(jnp.float32), st)
        y = y + p["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)                            # (B,1,h,p)
        new_cache = Mamba2Cache(conv=new_conv, ssm=st)
    else:
        y, final = _ssd_chunked(xs, dt, A, Bs.astype(jnp.float32),
                                Cs.astype(jnp.float32), chunk)
        y = y + p["D"][None, None, :, None] * xs
        y = y.astype(x.dtype)
        if cache is not None:
            new_cache = Mamba2Cache(conv=new_conv, ssm=final)

    y = y.reshape(Bsz, S, d_inner)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    return dense(p["out_proj"], y), new_cache
