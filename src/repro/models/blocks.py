"""Decoder blocks and scanned block groups.

A model is a stack of repeated *groups*; a group is a short sequence of
(mixer, ffn) blocks (one block for uniform archs, eight for Jamba's 1:7
Mamba:attention interleave).  Group parameters are stacked on a leading axis
and consumed by ``lax.scan`` — bounded HLO size and activation memory for
any depth, which keeps the 80-cell dry-run compile tractable.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .layers import (init_rms, rms_norm, init_attention, attention_fwd,
                     init_mlp, mlp_fwd, init_kv_cache, KVCache, rope_freqs)
from .mamba2 import init_mamba2, mamba2_fwd, init_mamba2_cache, Mamba2Cache
from .moe import init_moe, moe_fwd

__all__ = ["BlockSpec", "init_block", "block_fwd", "init_block_cache"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str                 # 'attn' | 'swa' | 'mamba' | 'cross_attn'
    ffn: Optional[str]         # 'dense' | 'moe' | None


def init_block(key, spec: BlockSpec, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_rms(cfg.d_model, dtype)}
    if spec.mixer in ("attn", "swa"):
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.d_head, dtype)
    elif spec.mixer == "cross_attn":
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.d_head, dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = init_mamba2(ks[0], cfg.d_model, cfg.ssm_state,
                                 headdim=cfg.ssm_headdim, dtype=dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn is not None:
        p["norm2"] = init_rms(cfg.d_model, dtype)
        if spec.ffn == "dense":
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        elif spec.ffn == "moe":
            p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe_d_ff,
                                cfg.moe_experts, cfg.moe_shared,
                                cfg.moe_d_ff_shared, dtype)
        else:
            raise ValueError(spec.ffn)
    return p


def init_block_cache(spec: BlockSpec, cfg, batch: int, s_max: int,
                     dtype=jnp.bfloat16):
    if spec.mixer in ("attn", "swa", "cross_attn"):
        smax = min(s_max, cfg.window) if (spec.mixer == "swa" and cfg.window
                                          and cfg.use_rolling_swa) else s_max
        return init_kv_cache(batch, cfg.n_kv_heads, smax, cfg.d_head, dtype)
    d_inner = 2 * cfg.d_model
    return init_mamba2_cache(batch, d_inner, cfg.ssm_state,
                             d_inner // cfg.ssm_headdim, cfg.ssm_headdim,
                             dtype)


def block_fwd(p, spec: BlockSpec, cfg, x, positions, freqs, *,
              cache=None, enc_out=None, causal=True,
              positions3=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(p["norm1"], x)
    new_cache = cache
    if spec.mixer in ("attn", "swa"):
        window = cfg.window if spec.mixer == "swa" else None
        out, new_cache = attention_fwd(
            p["attn"], h, positions, freqs,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            causal=causal, window=window, cache=cache,
            mrope_sections=cfg.mrope_sections, positions3=positions3)
    elif spec.mixer == "cross_attn":
        out, _ = attention_fwd(
            p["attn"], h, positions, freqs,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            causal=False, kv_x=enc_out)
    elif spec.mixer == "mamba":
        out, new_cache = mamba2_fwd(
            p["mamba"], h, d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
            chunk=cfg.ssm_chunk, cache=cache)
    x = x + out
    if spec.ffn is not None:
        h = rms_norm(p["norm2"], x)
        if spec.ffn == "dense":
            x = x + mlp_fwd(p["mlp"], h)
        else:
            y, aux = moe_fwd(p["moe"], h, top_k=cfg.moe_top_k,
                             capacity_factor=cfg.moe_capacity)
            x = x + y
    return x, new_cache, aux
