"""Shared transformer layers: norms, rotary embeddings (incl. M-RoPE),
GQA/MQA attention with sliding-window and KV-cache support, (Sw)iGLU MLP.

Pure functional: ``init_*`` build parameter pytrees (dict leaves), ``*_fwd``
apply them.  No collectives here — distribution is applied externally via
jit shardings, so every layer also runs single-device for smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "init_rms", "init_dense", "dense",
           "rope_freqs", "apply_rope", "apply_mrope",
           "init_attention", "attention_fwd", "init_mlp", "mlp_fwd",
           "KVCache", "init_kv_cache"]

# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def init_rms(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = (x32 * x32).mean(axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w.astype(dtype)}


def dense(p, x):
    return x @ p["w"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0):
    half = d_head // 2
    return 1.0 / (theta ** (np.arange(half) / half))   # (half,)


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def apply_rope(q, k, positions, freqs):
    """q/k: (B, S, H, Dh); positions: (B, S) int32."""
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(q.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(q.dtype)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


def apply_mrope(q, k, positions3, freqs, sections):
    """Qwen2-VL multimodal RoPE: positions3 (3, B, S) for (t, h, w);
    ``sections`` splits the half-dim across the three components."""
    half = freqs.shape[0]
    assert sum(sections) == half, (sections, half)
    angs = positions3[..., None].astype(jnp.float32) * freqs  # (3, B, S, half)
    parts, off = [], 0
    for i, s in enumerate(sections):
        parts.append(angs[i, :, :, off:off + s])
        off += s
    ang = jnp.concatenate(parts, axis=-1)                     # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(q.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(q.dtype)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


# ---------------------------------------------------------------------------
# attention (GQA / MQA, causal / bidirectional / sliding window, KV cache)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray        # (B, Hkv, Smax, Dh)
    v: jnp.ndarray        # (B, Hkv, Smax, Dh)
    pos: jnp.ndarray      # scalar int32 — tokens already cached


def init_kv_cache(batch: int, n_kv: int, s_max: int, d_head: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_kv, s_max, d_head), dtype),
        v=jnp.zeros((batch, n_kv, s_max, d_head), dtype),
        pos=jnp.zeros((), jnp.int32))


def init_attention(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d_model, n_heads * d_head, dtype),
        "wk": init_dense(ks[1], d_model, n_kv * d_head, dtype),
        "wv": init_dense(ks[2], d_model, n_kv * d_head, dtype),
        "wo": init_dense(ks[3], n_heads * d_head, d_model, dtype,
                         scale=1.0 / np.sqrt(n_heads * d_head)),
    }


def shard_hint(x, *spec):
    """Best-effort sharding constraint against the ambient mesh.

    Entries name mesh axes (or tuples of axes); axes missing from the
    ambient mesh or not dividing the dim are dropped; all other dims stay
    UNCONSTRAINED.  A no-op outside a `jax.sharding.set_mesh(...)` scope
    (single-device tests), so the model code stays mesh-agnostic."""
    from repro.compat import ambient_mesh, mesh_is_auto
    mesh = ambient_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return x
    # inside shard_map (Manual axes) data is already device-local — skip
    if not mesh_is_auto(mesh):
        return x
    from jax.sharding import PartitionSpec as P
    import numpy as _np
    clean = []
    used = False
    for i, a in enumerate(spec):
        if a is None:
            clean.append(P.UNCONSTRAINED)
            continue
        axes = (a,) if isinstance(a, str) else tuple(
            ax for ax in a if ax in mesh.axis_names)
        if axes and all(ax in mesh.axis_names for ax in axes):
            k = int(_np.prod([mesh.shape[ax] for ax in axes]))
            if k > 1 and x.shape[i] % k == 0:
                clean.append(axes[0] if len(axes) == 1 else axes)
                used = True
                continue
        clean.append(P.UNCONSTRAINED)
    if not used:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:
        from repro.compat import HAS_NEW_SHARDING
        if HAS_NEW_SHARDING:
            raise  # real spec/mesh bug — don't mask it on modern jax
        return x   # legacy jax: constraint unsupported in this context


BATCH_AXES = ("pod", "data")


def batch_hint(x):
    """Pin the leading (batch) dim of an activation to the data axes —
    XLA was observed to drop batch sharding through the layer scan when
    FSDP param shardings compete (EXPERIMENTS.md §Perf H2)."""
    if x.ndim < 2:
        return x
    return shard_hint(x, BATCH_AXES, *([None] * (x.ndim - 1)))


def residual_hint(x, seq_parallel: bool = False):
    """Residual-stream layout at layer boundaries: batch on the data axes
    and, when ``seq_parallel``, the sequence dim on 'model' (Megatron-SP
    style) — the layer-scan carry then stores 1/TP of each residual, the
    lever that fits the 95-layer train cells in HBM (§Perf H5)."""
    if x.ndim != 3:
        return batch_hint(x)
    if seq_parallel:
        return shard_hint(x, BATCH_AXES, "model", None)
    return batch_hint(x)


def _sdpa(q, k, v, mask, d_head):
    """q (B,S,H,Dh), k/v (B,Skv,Hkv,Dh); mask (B,1,S,Skv) bool.

    GQA is handled by repeating K/V up to H heads *at use* (a head-gather,
    cheap under SPMD) rather than a grouped (Hkv, g) einsum: the flat-head
    einsum partitions over the full 'model' axis, while the grouped form
    was observed to shard only g-ways.  Explicit head-dim hints keep the
    f32 logits sharded over 'model' (XLA was observed to replicate them
    otherwise — see EXPERIMENTS.md §Perf)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = shard_hint(q, None, None, "model", None)
    k = shard_hint(k, None, None, "model", None)
    v = shard_hint(v, None, None, "model", None)
    logits = jnp.einsum("bshd,bthd->bhst", q, k)     # (B,H,S,Skv)
    logits = shard_hint(logits, None, "model", None, None)
    logits = logits.astype(jnp.float32) / np.sqrt(d_head)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(B, S, H, Dh)


# query-chunked attention: bounds the logits working set to
# (B, H, q_chunk, Skv) per scan step instead of (B, H, S, Skv) — what makes
# 32k prefill fit HBM.  (Causal block-skipping is a §Perf candidate.)
Q_CHUNK = 1024


def _chunked_causal_sdpa(q, k, v, positions, window, d_head, q_chunk):
    B, S, H, Dh = q.shape
    nc = S // q_chunk
    qs = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, Dh), 1, 0)
    pq = jnp.moveaxis(positions.reshape(B, nc, q_chunk), 1, 0)
    kp = positions[:, None, :]                      # (B, 1, Skv)

    def body(_, inp):
        qc, pqc = inp
        valid = kp <= pqc[:, :, None]
        if window is not None:
            valid &= kp > pqc[:, :, None] - window
        out = _sdpa(qc, k, v, valid[:, None], d_head)
        return None, out

    # flash-style residency: recompute each chunk's f32 probs during the
    # backward pass instead of stacking them across the scan — the saved
    # residual per layer drops from O(S^2) f32 to one chunk (see §Perf H1)
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (qs, pq))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dh)


def attention_fwd(p, x, positions, freqs, *, n_heads: int, n_kv: int,
                  d_head: int, causal: bool = True,
                  window: Optional[int] = None,
                  cache: Optional[KVCache] = None,
                  kv_x: Optional[jnp.ndarray] = None,
                  mrope_sections=None, positions3=None):
    """Returns (out, new_cache).

    Modes:
      cache None, kv_x None      — full self-attention (train / scoring).
      cache given, S == q tokens — decode/prefill append: writes new K/V at
                                   cache.pos and attends over the cache.
      kv_x given                 — cross-attention onto kv_x (no rope).
    """
    B, S, D = x.shape
    q = dense(p["wq"], x).reshape(B, S, n_heads, d_head)
    src = x if kv_x is None else kv_x
    k = dense(p["wk"], src).reshape(B, src.shape[1], n_kv, d_head)
    v = dense(p["wv"], src).reshape(B, src.shape[1], n_kv, d_head)

    if kv_x is None:
        if mrope_sections is not None:
            q, k = apply_mrope(q, k, positions3, freqs, mrope_sections)
        else:
            q, k = apply_rope(q, k, positions, freqs)

    new_cache = None
    if cache is not None and kv_x is None:
        smax = cache.k.shape[2]
        rolling = window is not None and smax <= window
        kT = k.transpose(0, 2, 1, 3).astype(cache.k.dtype)
        vT = v.transpose(0, 2, 1, 3).astype(cache.v.dtype)
        q_pos = positions[:, :, None]            # (B, S, 1) global positions
        if rolling:
            # ring buffer of the last `smax` tokens (Mistral-style SWA cache)
            if S >= smax:
                idx = (cache.pos + S - smax + jnp.arange(smax)) % smax
                ck = cache.k.at[:, :, idx].set(kT[:, :, -smax:])
                cv = cache.v.at[:, :, idx].set(vT[:, :, -smax:])
            else:
                idx = (cache.pos + jnp.arange(S)) % smax
                ck = cache.k.at[:, :, idx].set(kT)
                cv = cache.v.at[:, :, idx].set(vT)
            new_cache = KVCache(k=ck, v=cv, pos=cache.pos + S)
            if S > 1:
                # prefill: the ring only retains the last `smax` keys, so
                # attention must run over the full fresh K/V (early queries
                # need in-window keys the ring has already evicted); the ring
                # write above still seeds subsequent decode steps.
                if S % Q_CHUNK == 0 and S > Q_CHUNK:
                    out = _chunked_causal_sdpa(q, k, v, positions, window,
                                               d_head, Q_CHUNK)
                else:
                    qp = positions[:, :, None]
                    kp = positions[:, None, :]
                    valid = (kp <= qp) & (kp > qp - window)
                    out = _sdpa(q, k, v, valid[:, None], d_head)
                out = dense(p["wo"], out.reshape(B, S, n_heads * d_head))
                return out, new_cache
            # decode: global position held by ring slot j after this write
            top = cache.pos + S - 1
            slots = jnp.arange(smax)[None, :]
            gpos = top - jnp.mod(top - slots, smax)   # (1, Smax)
            valid = (gpos[:, None, :] <= q_pos) & (gpos[:, None, :] >= 0)
            valid &= gpos[:, None, :] > q_pos - window
        else:
            ck = jax.lax.dynamic_update_slice(cache.k, kT, (0, 0, cache.pos, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, vT, (0, 0, cache.pos, 0))
            new_cache = KVCache(k=ck, v=cv, pos=cache.pos + S)
            kv_pos = jnp.arange(smax)[None, :]   # (1, Smax)
            valid = kv_pos[:, None, :] <= q_pos  # causal within cache
            valid &= (kv_pos < cache.pos + S)[:, None, :]
            if window is not None:
                valid &= (kv_pos[:, None, :] > q_pos - window)
        k_all = ck.transpose(0, 2, 1, 3)         # (B, Smax, Hkv, Dh)
        v_all = cv.transpose(0, 2, 1, 3)
        mask = valid[:, None]                    # (B,1,S,Smax)
        out = _sdpa(q, k_all, v_all, mask, d_head)
    else:
        Skv = src.shape[1]
        if kv_x is not None:
            out = _sdpa(q, k, v, jnp.ones((B, 1, S, Skv), bool), d_head)
        elif causal and S % Q_CHUNK == 0 and S > Q_CHUNK:
            out = _chunked_causal_sdpa(q, k, v, positions, window, d_head,
                                       Q_CHUNK)
        else:
            qp = positions[:, :, None]
            kp = positions[:, None, :]
            if causal:
                valid = kp <= qp
            else:
                valid = jnp.ones((B, S, Skv), bool)
            if window is not None:
                valid &= kp > qp - window
            out = _sdpa(q, k, v, valid[:, None], d_head)

    out = dense(p["wo"], out.reshape(B, S, n_heads * d_head))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wi": init_dense(ks[0], d_model, d_ff, dtype),
        "wg": init_dense(ks[1], d_model, d_ff, dtype),
        "wo": init_dense(ks[2], d_ff, d_model, dtype, scale=1.0 / np.sqrt(d_ff)),
    }


def mlp_fwd(p, x):
    return dense(p["wo"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x))
