"""Causal LM and encoder-decoder model classes over scanned block groups."""

from __future__ import annotations

import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (init_rms, rms_norm, init_dense, dense, rope_freqs,
                     init_kv_cache, KVCache, batch_hint, shard_hint,
                     BATCH_AXES, residual_hint)
from .blocks import BlockSpec, init_block, block_fwd, init_block_cache

__all__ = ["CausalLM", "EncDecLM", "build_model", "cross_entropy"]


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def cross_entropy(logits, targets, mask):
    """Mean next-token CE over masked positions; logits (B,S,V) any dtype."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


class CausalLM:
    """Decoder-only LM (dense / SWA / MoE / Mamba2 / hybrid / VLM backbone)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.freqs = jnp.asarray(rope_freqs(cfg.d_head or 64, cfg.rope_theta),
                                 jnp.float32)

    # -- params ---------------------------------------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.compute_dtype
        keys = jax.random.split(key, 4 + len(cfg.prelude))
        p: dict = {
            "embed": (jax.random.normal(keys[0], (cfg.vocab_padded, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
            "final_norm": init_rms(cfg.d_model, dtype),
            "lm_head": init_dense(keys[1], cfg.d_model, cfg.vocab_padded, dtype),
        }
        for i, spec in enumerate(cfg.prelude):
            p[f"prelude{i}"] = init_block(keys[4 + i], spec, cfg, dtype)
        gkeys = jax.random.split(keys[2], cfg.n_groups)
        groups = []
        for g in range(cfg.n_groups):
            bkeys = jax.random.split(gkeys[g], len(cfg.group))
            groups.append(tuple(init_block(bkeys[b], spec, cfg, dtype)
                                for b, spec in enumerate(cfg.group)))
        p["groups"] = _stack_trees(groups)
        return p

    # -- caches ----------------------------------------------------------------

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        pre = tuple(init_block_cache(spec, cfg, batch, s_max, dtype)
                    for spec in cfg.prelude)
        groups = []
        for g in range(cfg.n_groups):
            groups.append(tuple(init_block_cache(spec, cfg, batch, s_max, dtype)
                                for spec in cfg.group))
        return {"prelude": pre, "groups": _stack_trees(groups)}

    # -- forward ----------------------------------------------------------------

    def forward(self, params, tokens=None, *, embeds=None, positions=None,
                caches=None, positions3=None, train: bool = False):
        """Returns (logits, new_caches, aux_loss_sum)."""
        cfg = self.cfg
        if embeds is None:
            x = jnp.take(params["embed"], tokens, axis=0)
        else:
            x = embeds.astype(cfg.compute_dtype)
        x = batch_hint(x)
        B, S = x.shape[:2]
        if positions is None:
            base = 0 if caches is None else _first_cache_pos(caches)
            positions = base + jnp.arange(S)[None, :].astype(jnp.int32)
            positions = jnp.broadcast_to(positions, (B, S))
        aux = jnp.zeros((), jnp.float32)

        new_pre = []
        for i, spec in enumerate(cfg.prelude):
            c = None if caches is None else caches["prelude"][i]
            x, c, a = block_fwd(params[f"prelude{i}"], spec, cfg, x, positions,
                                self.freqs, cache=c, positions3=positions3)
            new_pre.append(c)
            aux = aux + a

        def group_body(x, scanned):
            gp, gc = scanned
            x = batch_hint(x)   # keep batch on the data axes (H2)
            a_sum = jnp.zeros((), jnp.float32)
            new_cs = []
            for b, spec in enumerate(cfg.group):
                c = None if gc is None else gc[b]

                def one_block(x, c, gpb=gp[b], spec=spec):
                    return block_fwd(gpb, spec, cfg, x, positions,
                                     self.freqs, cache=c,
                                     positions3=positions3)
                if train and cfg.remat and len(cfg.group) == 1:
                    # nested remat: backward holds one block's internals
                    # at a time instead of the whole layer's (§Perf H6)
                    one_block = jax.checkpoint(one_block)
                x, c, a = one_block(x, c)
                new_cs.append(c)
                a_sum = a_sum + a
            return x, (tuple(new_cs) if gc is not None else None, a_sum)

        body = jax.checkpoint(group_body) if (train and cfg.remat) else group_body
        gcaches = None if caches is None else caches["groups"]
        x, (new_gc, auxs) = jax.lax.scan(
            body, x, (params["groups"], gcaches))
        aux = aux + auxs.sum()

        x = batch_hint(rms_norm(params["final_norm"], x))
        logits = dense(params["lm_head"], x)
        # logits: batch on data axes, padded vocab on model (§Perf H3)
        logits = shard_hint(logits, BATCH_AXES, None, "model")
        new_caches = None
        if caches is not None:
            new_caches = {"prelude": tuple(new_pre), "groups": new_gc}
        return logits, new_caches, aux

    # -- losses -----------------------------------------------------------------

    def loss(self, params, batch, train: bool = True):
        logits, _, aux = self.forward(
            params, batch.get("tokens"), embeds=batch.get("embeds"),
            positions3=batch.get("positions3"), train=train)
        ce = cross_entropy(logits[:, :-1], batch["targets"][:, 1:],
                           batch["mask"][:, 1:].astype(jnp.float32))
        return ce + 0.01 * aux


def _first_cache_pos(caches):
    """Base query position = tokens already cached (scalar, from any KVCache;
    stacked group caches carry one pos per group — all equal, take [0])."""
    for leaf in jax.tree.leaves(
            caches, is_leaf=lambda x: isinstance(x, KVCache)):
        if isinstance(leaf, KVCache):
            p = leaf.pos
            return p if p.ndim == 0 else p.reshape(-1)[0]
    return jnp.zeros((), jnp.int32)


class EncDecLM:
    """Encoder-decoder (Seamless backbone): bidirectional encoder over stub
    frame embeddings, causal decoder with cross-attention."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.freqs = jnp.asarray(rope_freqs(cfg.d_head, cfg.rope_theta),
                                 jnp.float32)
        self.enc_group = (BlockSpec("attn", "dense"),)
        self.dec_group = (BlockSpec("attn", None), BlockSpec("cross_attn", "dense"))

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.compute_dtype
        keys = jax.random.split(key, 6)
        enc_groups, dec_groups = [], []
        ekeys = jax.random.split(keys[0], cfg.enc_layers)
        for g in range(cfg.enc_layers):
            bk = jax.random.split(ekeys[g], 1)
            enc_groups.append(tuple(init_block(bk[0], s, cfg, dtype)
                                    for s in self.enc_group))
        dkeys = jax.random.split(keys[1], cfg.n_groups)
        for g in range(cfg.n_groups):
            bk = jax.random.split(dkeys[g], len(self.dec_group))
            dec_groups.append(tuple(init_block(bk[b], s, cfg, dtype)
                                    for b, s in enumerate(self.dec_group)))
        return {
            "embed": (jax.random.normal(keys[2], (cfg.vocab_padded, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
            "enc_groups": _stack_trees(enc_groups),
            "enc_norm": init_rms(cfg.d_model, dtype),
            "dec_groups": _stack_trees(dec_groups),
            "final_norm": init_rms(cfg.d_model, dtype),
            "lm_head": init_dense(keys[3], cfg.d_model, cfg.vocab_padded, dtype),
        }

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        groups = []
        for g in range(cfg.n_groups):
            groups.append(tuple(init_block_cache(s, cfg, batch, s_max, dtype)
                                for s in self.dec_group))
        return {"groups": _stack_trees(groups)}

    def encode(self, params, frames, train: bool = False):
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

        def body(x, gp):
            x = batch_hint(x)
            for b, spec in enumerate(self.enc_group):
                x, _, _ = block_fwd(gp[b], spec, cfg, x, positions, self.freqs,
                                    causal=False)
            return x, None
        bodyf = jax.checkpoint(body) if (train and cfg.remat) else body
        x, _ = jax.lax.scan(bodyf, x, params["enc_groups"])
        return rms_norm(params["enc_norm"], x)

    def decode(self, params, tokens, enc_out, caches=None, train: bool = False):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        B, S = x.shape[:2]
        base = 0 if caches is None else _first_cache_pos(caches)
        positions = base + jnp.arange(S)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (B, S))

        def body(x, scanned):
            gp, gc = scanned
            x = batch_hint(x)
            new_cs = []
            for b, spec in enumerate(self.dec_group):
                c = None if gc is None else gc[b]
                x, c, _ = block_fwd(gp[b], spec, cfg, x, positions, self.freqs,
                                    cache=c, enc_out=enc_out)
                new_cs.append(c)
            return x, (tuple(new_cs) if gc is not None else None)

        bodyf = jax.checkpoint(body) if (train and cfg.remat) else body
        gcaches = None if caches is None else caches["groups"]
        x, new_gc = jax.lax.scan(bodyf, x, (params["dec_groups"], gcaches))
        x = batch_hint(rms_norm(params["final_norm"], x))
        logits = dense(params["lm_head"], x)
        logits = shard_hint(logits, BATCH_AXES, None, "model")
        return logits, (None if caches is None else {"groups": new_gc})

    def loss(self, params, batch, train: bool = True):
        enc = self.encode(params, batch["frames"], train=train)
        logits, _ = self.decode(params, batch["tokens"], enc, train=train)
        return cross_entropy(logits[:, :-1], batch["targets"][:, 1:],
                             batch["mask"][:, 1:].astype(jnp.float32))


def build_model(cfg):
    return EncDecLM(cfg) if cfg.encdec else CausalLM(cfg)
