"""Mixture-of-experts FFN: top-k routing, sort-based capacity dispatch,
optional shared experts (DeepSeekMoE-style fine-grained + shared).

Dispatch is token-local (sort by expert id into an (E, C, d) buffer), so no
all-to-all is required when expert weights are tensor-parallel over the
'model' mesh axis and tokens stay on 'data' — the combine reuses the same
TP all-reduce as a dense FFN.  Over-capacity tokens are dropped (standard
GShard/Switch semantics, capacity_factor 1.25 by default).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_dense, dense, init_mlp, mlp_fwd

__all__ = ["init_moe", "moe_fwd"]


def init_moe(key, d_model: int, d_ff_expert: int, n_experts: int,
             n_shared: int = 0, d_ff_shared: Optional[int] = None,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    sc_in = 1.0 / np.sqrt(d_model)
    sc_out = 1.0 / np.sqrt(d_ff_expert)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts), jnp.float32)
                   * 0.02).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (n_experts, d_model, d_ff_expert),
                                 jnp.float32) * sc_in).astype(dtype),
        "wg": (jax.random.normal(ks[2], (n_experts, d_model, d_ff_expert),
                                 jnp.float32) * sc_in).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_experts, d_ff_expert, d_model),
                                 jnp.float32) * sc_out).astype(dtype),
    }
    if n_shared > 0:
        dsh = d_ff_shared if d_ff_shared is not None else n_shared * d_ff_expert
        p["shared"] = init_mlp(ks[4], d_model, dsh, dtype)
    return p


def moe_fwd(p, x, *, top_k: int, capacity_factor: float = 1.25
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out, aux_loss).

    Under a multi-device ambient mesh this dispatches token-locally inside
    shard_map: the argsort-based capacity dispatch is NOT expressible as a
    sharded global op (XLA replicates the full token array to sort it —
    observed 97 GB/layer of all-reduce on grok prefill, §Perf H7), so each
    device routes its own tokens against the F-sharded expert weights and
    one psum over 'model' replaces the dense-FFN TP reduction."""
    dist = _dist_plan(x)
    if dist is not None:
        return _moe_fwd_dist(p, x, top_k=top_k,
                             capacity_factor=capacity_factor, plan=dist)
    return _moe_fwd_local(p, x, top_k=top_k, capacity_factor=capacity_factor)


def _dist_plan(x):
    """(batch_axes, model_axis?) if a usable ambient mesh is present."""
    from repro.compat import ambient_mesh, mesh_is_auto
    mesh = ambient_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    # only under fully-Auto meshes (nested shard_map is not allowed)
    if not mesh_is_auto(mesh):
        return None
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names
                  and mesh.shape[a] > 1)
    if not baxes:
        return None
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    if x.shape[0] % nb != 0:
        return None
    m_ax = "model" if ("model" in mesh.axis_names
                       and mesh.shape["model"] > 1) else None
    return (mesh, baxes, m_ax)


def _moe_fwd_dist(p, x, *, top_k, capacity_factor, plan):
    mesh, baxes, m_ax = plan
    from jax.sharding import PartitionSpec as P
    bspec = P(baxes if len(baxes) > 1 else baxes[0])
    fspec = lambda *dims: P(*dims)
    F = p["wi"].shape[-1]
    f_ok = m_ax is not None and F % mesh.shape[m_ax] == 0
    wi_spec = P(None, None, m_ax) if f_ok else P()
    wo_spec = P(None, m_ax, None) if f_ok else P()
    has_shared = "shared" in p
    if has_shared:
        Fs = p["shared"]["wi"]["w"].shape[-1]
        s_ok = f_ok and Fs % mesh.shape[m_ax] == 0
        swi_spec = P(None, m_ax) if s_ok else P()
        swo_spec = P(m_ax, None) if s_ok else P()

    def block(x, router, wi, wg, wo, *shared_w):
        pp = {"router": router, "wi": wi, "wg": wg, "wo": wo}
        if has_shared:
            pp["shared"] = {"wi": {"w": shared_w[0]}, "wg": {"w": shared_w[1]},
                            "wo": {"w": shared_w[2]}}
        out, aux = _moe_fwd_local(pp, x, top_k=top_k,
                                  capacity_factor=capacity_factor)
        if f_ok:
            out = jax.lax.psum(out, m_ax)      # F-contraction partial sums
        aux = jax.lax.pmean(aux, baxes)
        return out, aux

    args = [x, p["router"], p["wi"], p["wg"], p["wo"]]
    in_specs = [P(bspec[0], None, None), P(), wi_spec, wi_spec, wo_spec]
    if has_shared:
        args += [p["shared"]["wi"]["w"], p["shared"]["wg"]["w"],
                 p["shared"]["wo"]["w"]]
        in_specs += [swi_spec, swi_spec, swo_spec]
    from repro.compat import shard_map as _shard_map
    out, aux = _shard_map(
        block, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(P(bspec[0], None, None), P()), check_vma=False)(*args)
    return out, aux


def _moe_fwd_local(p, x, *, top_k: int, capacity_factor: float = 1.25
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E = p["router"].shape[1]
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                  # (T, k)
    topv = topv / (topv.sum(axis=-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * (me * ce).sum()

    TK = T * top_k
    eid = topi.reshape(-1)                                    # (TK,)
    src = jnp.repeat(jnp.arange(T), top_k)
    wgt = topv.reshape(-1)

    order = jnp.argsort(eid)
    eid_s, src_s, wgt_s = eid[order], src[order], wgt[order]
    counts = jnp.zeros((E,), jnp.int32).at[eid_s].add(1)
    offsets = jnp.cumsum(counts) - counts                     # start of expert
    pos = jnp.arange(TK) - offsets[eid_s]
    cap = int(np.ceil(TK / E * capacity_factor / 8.0) * 8)
    keep = pos < cap
    dest = jnp.where(keep, eid_s * cap + pos, E * cap)        # dump slot

    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    buf = buf.at[dest].set(xf[src_s])
    xe = buf[:-1].reshape(E, cap, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(x.dtype))) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))

    flat = ye.reshape(E * cap, D)
    contrib = jnp.where(keep[:, None], flat[jnp.where(keep, dest, 0)]
                        * wgt_s[:, None].astype(x.dtype), 0)
    out = jnp.zeros((T, D), x.dtype).at[src_s].add(contrib)

    if "shared" in p:
        out = out + mlp_fwd(p["shared"], xf)
    return out.reshape(B, S, D), aux
