"""Version tolerance for the jax sharding API surface.

The repo targets the modern API (``jax.shard_map``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.sharding.set_mesh``); older installations (such as
the 0.4.x line) expose the same functionality under different names or not
at all.  Everything sharding-adjacent goes through this module so the rest
of the codebase is written once against one surface:

  shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=False)
  make_mesh(shape, axes, axis_types=None, devices=None)
  set_mesh(mesh)          -- context manager
  ambient_mesh()          -- abstract mesh if set, else the physical one
  mesh_is_auto(mesh)      -- True iff every axis is Auto (or untyped)
  AxisType                -- enum with .Auto (polyfilled when absent)
"""

from __future__ import annotations

import enum
from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["shard_map", "make_mesh", "set_mesh", "ambient_mesh",
           "mesh_is_auto", "AxisType", "HAS_NEW_SHARDING"]

HAS_NEW_SHARDING = hasattr(jax, "shard_map")


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with a fallback to the experimental implementation.

    ``check_vma`` maps onto the legacy ``check_rep`` flag (both gate the
    replication/varying-manual-axes checker).
    """
    if HAS_NEW_SHARDING:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types=None, devices=None) -> Mesh:
    """``jax.make_mesh`` accepting (and dropping, when unsupported) axis_types."""
    if axis_types is not None:
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=tuple(axis_types),
                                 devices=devices)
        except TypeError:
            pass
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             devices=devices)
    except (TypeError, AttributeError):
        devs = list(jax.devices()) if devices is None else list(devices)
        n = int(np.prod(tuple(axis_shapes)))
        return Mesh(np.asarray(devs[:n]).reshape(tuple(axis_shapes)),
                    tuple(axis_names))


def auto_axes(n: int):
    """n Auto axis types (for forwarding into make_mesh)."""
    return (AxisType.Auto,) * n


def set_mesh(mesh: Mesh):
    """Ambient-mesh scope: ``jax.sharding.set_mesh`` or the legacy
    ``with mesh:`` thread-resources context (which serves the same role for
    PartitionSpec-based ``with_sharding_constraint``)."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh  # Mesh is a context manager on older jax


def ambient_mesh() -> Optional[Mesh]:
    """The mesh of the enclosing set_mesh scope, or None."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        try:
            m = jax.sharding.get_abstract_mesh()
        except Exception:
            return None
        if m is None or not getattr(m, "axis_names", ()):
            return None
        return m
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None
    if m is None or m.empty:
        return None
    return m


def mesh_is_auto(mesh) -> bool:
    """True iff no axis of ``mesh`` is Manual/Explicit (untyped counts as
    Auto — the legacy mesh has no axis types at all)."""
    try:
        return all(t == AxisType.Auto
                   for t in getattr(mesh, "axis_types", ()))
    except Exception:
        return False


