"""Structured findings and the committed waiver file.

A :class:`Finding` is one rule violation: rule id, location (``file:line``
for AST rules, ``ir:<engine>/<precision>/<variant>`` for IR rules), a
one-line message, and a fix-it hint.  Waivers live in a committed text
file so the gate starts green and every suppression carries a rationale
reviewed like code.

Waiver file syntax (one per line, ``#`` starts the rationale/comment)::

    AL-DEAD  src/repro/launch/train.py   # CLI entry point, example-driven
    IR-C     ir:dsim_dist/f32/*          # <why this config is exempt>

The location pattern is fnmatch-matched against the finding location with
any trailing ``:line`` stripped — waivers must not rot when a file is
edited above the waived line.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import List, Optional, Tuple

__all__ = ["Finding", "Waivers", "render_report"]


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # "IR-A".."IR-F", "AL-RANDOM", "AL-KEY", ...
    loc: str             # "src/repro/x.py:123" | "ir:lattice/int8/degrade"
    msg: str             # one-line statement of the violation
    hint: str = ""       # how to fix (or how to waive with a rationale)

    @property
    def loc_base(self) -> str:
        """Location with any trailing line number stripped (waiver key)."""
        head, sep, tail = self.loc.rpartition(":")
        if sep and tail.isdigit():
            return head
        return self.loc

    def render(self) -> str:
        s = f"{self.rule:10s} {self.loc}: {self.msg}"
        if self.hint:
            s += f"\n{'':10s} fix: {self.hint}"
        return s

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Waivers:
    """Parsed waiver file: (rule, location-pattern, rationale) triples."""

    def __init__(self, entries: List[Tuple[str, str, str]],
                 path: Optional[str] = None):
        self.entries = entries
        self.path = path
        self._hits = [0] * len(entries)

    @classmethod
    def load(cls, path) -> "Waivers":
        entries = []
        try:
            with open(path) as f:
                lines = f.readlines()
        except FileNotFoundError:
            return cls([], path=str(path))
        for ln in lines:
            code, _, rationale = ln.partition("#")
            parts = code.split()
            if not parts:
                continue
            if len(parts) != 2 or not rationale.strip():
                raise ValueError(
                    f"{path}: bad waiver line {ln.rstrip()!r} — expected "
                    "'RULE location-pattern  # rationale'")
            entries.append((parts[0], parts[1], rationale.strip()))
        return cls(entries, path=str(path))

    def match(self, finding: Finding) -> Optional[str]:
        """Rationale of the first waiver covering this finding, else None."""
        for i, (rule, pat, rationale) in enumerate(self.entries):
            if rule == finding.rule and (
                    fnmatch.fnmatch(finding.loc_base, pat)
                    or fnmatch.fnmatch(finding.loc, pat)):
                self._hits[i] += 1
                return rationale
        return None

    def unused(self) -> List[Tuple[str, str, str]]:
        """Waivers that matched nothing this run (candidates for removal)."""
        return [e for e, h in zip(self.entries, self._hits) if h == 0]


def render_report(sections: dict, waivers: Waivers,
                  json_path: Optional[str] = None) -> Tuple[str, int]:
    """(report text, exit code) for {section: [Finding, ...]}.

    Waived findings are listed with their rationale and don't gate; the
    exit code is the number of unwaived findings (0 == green).
    """
    lines, unwaived_total = [], 0
    payload = {}
    for name, findings in sections.items():
        active, waived = [], []
        for f in findings:
            rationale = waivers.match(f)
            (waived if rationale is not None else active).append(
                (f, rationale))
        unwaived_total += len(active)
        lines.append(f"== {name}: {len(active)} finding(s)"
                     f"{f', {len(waived)} waived' if waived else ''} ==")
        for f, _ in active:
            lines.append(f.render())
        for f, rationale in waived:
            lines.append(f"  [waived: {rationale}] {f.rule} {f.loc}")
        payload[name] = {
            "findings": [f.as_dict() for f, _ in active],
            "waived": [dict(f.as_dict(), rationale=r) for f, r in waived],
        }
    for rule, pat, rationale in waivers.unused():
        lines.append(f"note: unused waiver {rule} {pat!r} ({rationale})")
    verdict = "CLEAN" if unwaived_total == 0 else "FAIL"
    lines.append(f"analyze: {verdict} — {unwaived_total} unwaived "
                 "finding(s)")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"sections": payload,
                       "unwaived": unwaived_total}, f, indent=2)
    return "\n".join(lines), (1 if unwaived_total else 0)
