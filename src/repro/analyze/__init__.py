"""Static contract auditor for the p-bit machine's structural guarantees.

The repo's headline contracts — couplings never leave local memory, devices
exchange nothing but the declared packed boundary payloads, the integer
inner loop contains zero floating point, counters are uint32-modular — are
properties of the *lowered program*, not of any particular run.  This
package checks them statically, in milliseconds, on a single-device host:

* :mod:`repro.analyze.ir_rules` — Layer 1: walk every registered engine x
  precision x (sync, degrade) configuration through ``trace_chunk`` (over
  an ``AbstractMesh``, so mesh collectives appear without multi-device
  backing) and assert the IR-A..IR-F contract rules on the jaxpr.
* :mod:`repro.analyze.lint` — Layer 2: repo-specific AST rules over
  ``src/`` (AL-RANDOM, AL-KEY, AL-LOCK, AL-EXCEPT).
* :mod:`repro.analyze.deadcode` — tier-1 import-graph reachability
  (AL-DEAD) and the dead-code report.
* :mod:`repro.analyze.runner` — orchestration, waiver file handling, and
  the report format shared by ``tools/repro_analyze.py``.

Run the gate locally with ``python tools/repro_analyze.py`` (see the
"Static analysis" section of DESIGN.md for the rule catalogue).
"""

from .findings import Finding, Waivers  # noqa: F401
from .runner import run_ir, run_lint, run_deadcode, run_all  # noqa: F401
