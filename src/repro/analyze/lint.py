"""Layer 2 — repo-specific AST lint over ``src/``.

Four rules, each born from a real defect class in this repo's history:

  AL-RANDOM  host randomness / wall-clock calls inside traced functions
             (they freeze at trace time and silently repeat per call)
  AL-KEY     unhashable values (arrays, lists, dicts) used in cache/pool
             keys — the PR-5 engine-pool crash class; keys must be
             hashable by construction (digest arrays first)
  AL-LOCK    attributes annotated ``# guarded_by: <lock>`` accessed
             outside ``with self.<lock>:`` / ``# lock_held:`` methods —
             the PR-8 ``stats()`` torn-read class
  AL-EXCEPT  silent ``except: pass`` around collective/exchange calls
             (swallowing a boundary failure desynchronizes the mesh)

Pure ``ast`` + ``tokenize`` — no imports of the scanned code, so the lint
can never be broken by an import-time crash in the target.
"""

from __future__ import annotations

import ast
import io
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .findings import Finding

__all__ = ["lint_file", "lint_tree", "LINT_RULES"]

_TRACE_ENTRY_FUNCS = {
    "scan", "while_loop", "fori_loop", "cond", "switch", "vmap", "pmap",
    "jit", "shard_map", "checkpoint", "remat", "custom_jvp", "custom_vjp",
    "associative_scan",
}

_BANNED_IN_TRACE = {
    # host RNG: traces to a constant, not a random stream
    "np.random", "numpy.random", "random.random", "random.randint",
    "random.choice", "random.shuffle", "random.uniform", "random.gauss",
    "random.sample", "random.randrange",
    # wall clock: freezes at trace time
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
    "time.perf_counter_ns", "datetime.now", "datetime.datetime.now",
    "datetime.utcnow",
}

_ARRAY_CONSTRUCTORS = {
    "np.array", "np.asarray", "np.zeros", "np.ones", "np.arange",
    "np.empty", "np.full", "numpy.array", "numpy.asarray",
    "jnp.array", "jnp.asarray", "jnp.zeros", "jnp.ones", "jnp.arange",
    "jax.numpy.array", "jax.numpy.asarray",
}

_KEYED_CONTAINER_MARKERS = ("cache", "pool", "memo")

_COLLECTIVE_CALL_MARKERS = (
    "all_gather", "ppermute", "psum", "pmax", "pmin", "all_to_all",
    "exchange",
)


def _dotted(node: ast.AST) -> Optional[str]:
    """'np.random.rand' for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _comments_by_line(source: str) -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except tokenize.TokenizeError:
        pass
    return out


# ---------------------------------------------------------------- AL-RANDOM

def _traced_functions(tree: ast.Module) -> Set[ast.AST]:
    """Function defs that run under JAX tracing.

    A function is traced if it is decorated with jit/vmap/etc. (directly
    or via functools.partial) or passed by name as an argument to a
    trace-entry call (jax.lax.scan, shard_map, ...).
    """
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    traced: Set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = _dotted(target) or ""
                leaf = name.rsplit(".", 1)[-1]
                if leaf in _TRACE_ENTRY_FUNCS:
                    traced.add(node)
                elif leaf == "partial" and isinstance(dec, ast.Call):
                    inner = [_dotted(a) or "" for a in dec.args]
                    if any(n.rsplit(".", 1)[-1] in _TRACE_ENTRY_FUNCS
                           for n in inner):
                        traced.add(node)
        elif isinstance(node, ast.Call):
            name = _dotted(node.func) or ""
            if name.rsplit(".", 1)[-1] not in _TRACE_ENTRY_FUNCS:
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in defs:
                    traced.update(defs[arg.id])

    # tracing is transitive into lexically nested defs
    closure: Set[ast.AST] = set()
    for fn in traced:
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                closure.add(sub)
    return closure


def rule_random(path: str, tree: ast.Module, source: str,
                comments: Dict[int, str]) -> List[Finding]:
    out: List[Finding] = []
    for fn in _traced_functions(tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func) or ""
            if name in _BANNED_IN_TRACE or any(
                    name.startswith(b + ".") for b in
                    ("np.random", "numpy.random")):
                out.append(Finding(
                    "AL-RANDOM", f"{path}:{node.lineno}",
                    f"`{name}` inside traced function `{fn.name}` — the "
                    "value freezes at trace time",
                    "thread a jax PRNG key / LFSR state through the "
                    "computation, or hoist the call to the host driver"))
    return out


# ------------------------------------------------------------------- AL-KEY

def _array_like_names(fn: ast.AST) -> Set[str]:
    """Names assigned from array constructors within this function."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            cname = _dotted(node.value.func) or ""
            if cname in _ARRAY_CONSTRUCTORS:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
    return names


def _key_exprs(node: ast.AST) -> Iterable[Tuple[ast.AST, ast.AST]]:
    """(container expr, key expr) for cache/pool-style keyed stores."""
    if isinstance(node, ast.Assign):
        for t in node.targets:
            if isinstance(t, ast.Subscript):
                yield t.value, t.slice
    elif isinstance(node, ast.Subscript) and isinstance(node.ctx, ast.Load):
        yield node.value, node.slice
    elif isinstance(node, ast.Call):
        name = _dotted(node.func) or ""
        if name.rsplit(".", 1)[-1] in ("get", "setdefault", "pop") \
                and isinstance(node.func, ast.Attribute) and node.args:
            yield node.func.value, node.args[0]


def _is_keyed_container(expr: ast.AST) -> bool:
    name = (_dotted(expr) or "").lower()
    return any(m in name for m in _KEYED_CONTAINER_MARKERS)


def _unhashable_part(key: ast.AST, array_names: Set[str]) -> Optional[str]:
    parts = list(key.elts) if isinstance(key, ast.Tuple) else [key]
    for p in parts:
        if isinstance(p, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
            return type(p).__name__.lower()
        if isinstance(p, ast.Call):
            cname = _dotted(p.func) or ""
            if cname in _ARRAY_CONSTRUCTORS:
                return cname
        if isinstance(p, ast.Name) and p.id in array_names:
            return f"array-valued `{p.id}`"
    return None


def rule_key(path: str, tree: ast.Module, source: str,
             comments: Dict[int, str]) -> List[Finding]:
    out: List[Finding] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Module)):
            continue
        array_names = _array_like_names(fn)
        body = fn.body if isinstance(fn, ast.Module) else [fn]
        for stmt in body:
            for node in ast.walk(stmt):
                for container, key in _key_exprs(node):
                    if not _is_keyed_container(container):
                        continue
                    bad = _unhashable_part(key, array_names)
                    if bad is None:
                        continue
                    out.append(Finding(
                        "AL-KEY", f"{path}:{node.lineno}",
                        f"cache/pool key into "
                        f"`{_dotted(container) or '<expr>'}` contains "
                        f"unhashable {bad}",
                        "build keys hashable by construction — digest "
                        "arrays (see serve._hashable_kw) and use tuples, "
                        "never lists/dicts/raw ndarrays"))
    return out


# ------------------------------------------------------------------ AL-LOCK

def _guard_decls(cls: ast.ClassDef, comments: Dict[int, str]):
    """(guarded: attr -> lock, aliases: attr -> lock) from __init__."""
    guarded: Dict[str, str] = {}
    aliases: Dict[str, str] = {}
    for meth in cls.body:
        if not (isinstance(meth, ast.FunctionDef)
                and meth.name == "__init__"):
            continue
        for node in ast.walk(meth):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            cm = comments.get(node.lineno, "")
            for t in targets:
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    continue
                if "guarded_by:" in cm:
                    guarded[t.attr] = cm.split("guarded_by:")[1].split()[0]
                elif "lock_alias:" in cm:
                    aliases[t.attr] = cm.split("lock_alias:")[1].split()[0]
    return guarded, aliases


def _with_lock_spans(meth: ast.FunctionDef, locks: Set[str]):
    """Line spans of ``with self.<lock>:`` blocks (lexical containment)."""
    spans = []
    for node in ast.walk(meth):
        if not isinstance(node, ast.With):
            continue
        for item in node.items:
            ce = item.context_expr
            if isinstance(ce, ast.Attribute) \
                    and isinstance(ce.value, ast.Name) \
                    and ce.value.id == "self" and ce.attr in locks:
                spans.append((node.lineno, node.end_lineno))
    return spans


def rule_lock(path: str, tree: ast.Module, source: str,
              comments: Dict[int, str]) -> List[Finding]:
    out: List[Finding] = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        guarded, aliases = _guard_decls(cls, comments)
        if not guarded:
            continue
        for meth in cls.body:
            if not isinstance(meth, ast.FunctionDef) \
                    or meth.name == "__init__":
                continue
            held: Set[str] = set()
            for ln in range(meth.lineno, min(meth.body[0].lineno,
                                             meth.lineno + 3) + 1):
                cm = comments.get(ln, "")
                if "lock_held:" in cm:
                    held.add(cm.split("lock_held:")[1].split()[0])
            for node in ast.walk(meth):
                if not (isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and node.attr in guarded):
                    continue
                lock = guarded[node.attr]
                ok_locks = {lock} | {a for a, l in aliases.items()
                                     if l == lock}
                if lock in held or held & set(
                        a for a, l in aliases.items() if l == lock):
                    continue
                spans = _with_lock_spans(meth, ok_locks)
                if any(lo <= node.lineno <= hi for lo, hi in spans):
                    continue
                out.append(Finding(
                    "AL-LOCK", f"{path}:{node.lineno}",
                    f"`self.{node.attr}` (guarded_by: {lock}) accessed in "
                    f"`{cls.name}.{meth.name}` outside `with "
                    f"self.{lock}:`",
                    f"take the lock, or annotate the method "
                    f"`# lock_held: {lock}` if every caller holds it"))
    return out


# ---------------------------------------------------------------- AL-EXCEPT

def _is_silent(handler: ast.ExceptHandler) -> bool:
    for stmt in handler.body:
        if isinstance(stmt, ast.Pass) or isinstance(stmt, ast.Continue):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value,
                                                     ast.Constant):
            continue  # docstring / Ellipsis
        return False
    return True


def rule_except(path: str, tree: ast.Module, source: str,
                comments: Dict[int, str]) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Try):
            continue
        calls = []
        for sub in node.body:
            for n in ast.walk(sub):
                if isinstance(n, ast.Call):
                    name = (_dotted(n.func) or "").rsplit(".", 1)[-1]
                    if any(m in name for m in _COLLECTIVE_CALL_MARKERS):
                        calls.append(name)
        if not calls:
            continue
        for handler in node.handlers:
            if _is_silent(handler):
                out.append(Finding(
                    "AL-EXCEPT", f"{path}:{handler.lineno}",
                    f"silent except around collective/exchange call(s) "
                    f"{sorted(set(calls))}",
                    "a swallowed boundary failure desynchronizes the "
                    "mesh — record it in the health state or re-raise"))
    return out


LINT_RULES = (rule_random, rule_key, rule_lock, rule_except)


def lint_file(path: Path, rel: str) -> List[Finding]:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding("AL-PARSE", f"{rel}:{e.lineno or 0}",
                        f"syntax error: {e.msg}", "")]
    comments = _comments_by_line(source)
    out: List[Finding] = []
    for rule in LINT_RULES:
        out.extend(rule(rel, tree, source, comments))
    return out


def lint_tree(root: Path, subdir: str = "src") -> List[Finding]:
    out: List[Finding] = []
    for path in sorted((root / subdir).rglob("*.py")):
        out.extend(lint_file(path, str(path.relative_to(root))))
    return out
