"""Audit configuration enumeration: every engine x precision x variant.

Builds small fixed problems once, traces each registered configuration
through its ``trace_chunk`` hook over an ``AbstractMesh`` (mesh
collectives appear in the jaxpr without any multi-device backing), and
attaches the declared contracts the IR rules check against:

* predicted collective executions per chunk, derived from the sync_every
  staleness schedule (IR-C);
* the wire payload dtype/bytes from ``boundary_payload()`` (dist) or the
  brick face-plane math (lattice) (IR-B);
* the flat output indices of the chunk-crossing counters (IR-E);
* the ``fused_working_set_bytes`` VMEM model for the lattice (IR-F).

Coverage is driven by ``ENGINE_PRECISIONS`` itself, so registering a new
precision without extending the audit table fails loudly here rather
than silently shrinking the gate.
"""

from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from .ir_rules import ChunkAudit

__all__ = ["build_audits", "trace_failures"]

# mesh extent along the sharded axis of each toy problem
_K = 2
# chunk shapes: enough iterations that per-iteration vs per-sweep vs
# per-color exchange schedules produce distinct counts
_ITERS, _S = 4, 4


def _problems():
    from jax.sharding import AbstractMesh
    from repro.core.coloring import greedy_coloring
    from repro.core.dsim import build_partitioned
    from repro.core.graph import random_regular
    from repro.core.lattice import build_ea3d_lattice
    from repro.core.partition import greedy_partition

    g = random_regular(24, 3, seed=0)
    col = greedy_coloring(np.asarray(g.idx), np.asarray(g.w))
    labels = greedy_partition(np.asarray(g.idx), np.asarray(g.w), _K, seed=0)
    prob = build_partitioned(g, col, np.asarray(labels, np.int32), _K)
    lat = build_ea3d_lattice(8, seed=5)
    return (g, prob, lat,
            AbstractMesh((("data", _K),)), AbstractMesh((("x", _K),)))


def _dist_payload(eng):
    """(allowed payload dtypes, allowed device-local payload bytes).

    The degraded exchange adds (2,) uint32 integrity headers but ships
    the same payload format as the plain path (boundary_payload()).
    """
    R, b_pad = eng.replicas, eng.b_pad
    if eng.precision == "bitplane":
        return (np.dtype(np.uint32),), (4 * eng.words * b_pad,)
    if eng.mode == "cmft":
        return (np.dtype(np.float32),), (4 * R * b_pad,)
    if eng.bitpack:
        return (np.dtype(np.uint8),), (R * b_pad // 8,)
    return (np.dtype(np.int8),), (R * b_pad,)


def _lattice_payload(eng):
    """Allowed (dtypes, bytes) for every wired face plane of the brick."""
    from repro.core.packing import pad_to_multiple
    bx, by, bz = eng.brick
    faces = {0: by * bz, 1: bx * bz, 2: bx * by}
    wired = [i for i, (a, k) in enumerate(zip(eng.dim_axes, eng.nb))
             if a is not None and k > 1]
    if eng.precision == "bitplane":
        dts: Tuple[np.dtype, ...] = (np.dtype(np.uint32),)
        sizes = tuple(4 * eng.words * faces[i] for i in wired)
    elif eng.bitpack_halos:
        dts = (np.dtype(np.uint8),)
        sizes = tuple(pad_to_multiple(eng.replicas * faces[i], 8) // 8
                      for i in wired)
    else:
        dts = (np.dtype(np.int8),)
        sizes = tuple(eng.replicas * faces[i] for i in wired)
    return dts, tuple(sorted(set(sizes))), len(wired)


def _dist_predict(eng, iters: int, S: int, sync, degrade: bool):
    """Collective executions per chunk from the staleness schedule."""
    if sync == "phase":
        gathers = iters * S * len(eng._consts["color_slots"])
    elif sync is None:
        gathers = 0
    else:
        gathers = iters * S // int(sync)   # one publication per sync sweeps
    if degrade:
        gathers *= 2             # + one (2,) uint32 header per exchange
    out = {"psum": 1}            # final chunk-level energy reduction
    if gathers:
        out["all_gather"] = gathers
    return out


def _lattice_predict(iters: int, n_wired: int, degrade: bool):
    perms = iters * 2 * n_wired  # lo+hi face per wired axis per iteration
    if degrade:
        perms *= 2               # + header ppermute per face exchange
    out = {"psum": 1}
    if perms:
        out["ppermute"] = perms
    if degrade:
        out["pmax"] = 5          # end-of-chunk mesh-wide health consensus
    return out


def _iter_audit_specs() -> Iterator[tuple]:
    """(engine, precision, variant, build kwargs, trace kwargs)."""
    from repro.engines.base import ENGINE_PRECISIONS

    for engine, precisions in ENGINE_PRECISIONS.items():
        for prec in precisions:
            R = 32 if prec == "bitplane" else 1
            base = {"precision": prec, "replicas": R}
            if engine == "gibbs":
                yield engine, prec, "plain", dict(base, rng="lfsr"), {}
            elif engine == "dsim":
                for sync in (4, "phase", None):
                    yield (engine, prec, f"sync={sync}",
                           dict(base, rng="lfsr"), {"sync": sync})
            elif engine == "dsim_dist":
                for sync in (4, "phase", None):
                    yield (engine, prec, f"sync={sync}",
                           dict(base, rng="lfsr"), {"sync": sync})
                yield (engine, prec, "degrade",
                       dict(base, rng="lfsr"), {"sync": 4, "degrade": True})
                yield (engine, prec, "degrade+codes",
                       dict(base, rng="lfsr"),
                       {"sync": 4, "degrade": True, "has_codes": True})
                if prec == "f32":
                    yield (engine, prec, "philox/phase",
                           dict(base, rng="philox"), {"sync": "phase"})
                    yield (engine, prec, "cmft",
                           dict(base, rng="lfsr", mode="cmft"), {"sync": 4})
                    yield (engine, prec, "nobitpack/sync=None",
                           dict(base, rng="lfsr", bitpack=False),
                           {"sync": None})
            else:  # lattice
                yield engine, prec, "plain", dict(base), {}
                yield engine, prec, "degrade", dict(base), {"degrade": True}
                yield (engine, prec, "degrade+codes", dict(base),
                       {"degrade": True, "has_codes": True})


# flat output index of each chunk-crossing counter (register_dataclass
# flattening follows field order; degrade runners append the 6-leaf
# health tuple whose first leaf is the exchange seq counter)
_FLIPS_IDX = {"gibbs": 4, "dsim": 5, "dsim_dist": 5, "lattice": 9}
_STATE_LEAVES = {"dsim_dist": 6, "lattice": 10}


def build_audits() -> Tuple[List[ChunkAudit], List[Tuple[str, str]]]:
    """Trace every configuration; returns (audits, trace failures).

    A configuration that fails to trace is itself a contract violation
    (the audit hooks are part of the engine API) — the runner turns each
    failure into an IR-TRACE finding rather than crashing the gate.
    """
    from repro.engines.registry import make_engine

    g, prob, lat, amesh_d, amesh_x = _problems()
    audits: List[ChunkAudit] = []
    failures: List[Tuple[str, str]] = []

    for engine, prec, variant, mk_kw, tr_kw in _iter_audit_specs():
        loc = f"ir:{engine}/{prec}/{variant}"
        try:
            if engine == "gibbs":
                h = make_engine("gibbs", g, **mk_kw)
            elif engine == "dsim":
                h = make_engine("dsim", prob, **mk_kw)
            elif engine == "dsim_dist":
                h = make_engine("dsim_dist", prob, mesh=amesh_d, **mk_kw)
            else:
                h = make_engine("lattice", lattice=lat, mesh=amesh_x,
                                dim_axes=("x", None, None), impl="ref",
                                **mk_kw)
            traced = h.trace_chunk(_ITERS, _S, **tr_kw)
        except Exception as e:  # noqa: BLE001 — reported, not swallowed
            failures.append((loc, f"{type(e).__name__}: {e}"))
            continue

        eng = h.eng
        degrade = bool(tr_kw.get("degrade"))
        counters = {"flips": _FLIPS_IDX[engine]}
        working_set = None
        if engine in ("gibbs", "dsim"):
            predicted: dict = {}
            dts: Tuple[np.dtype, ...] = ()
            sizes: Tuple[int, ...] = ()
        elif engine == "dsim_dist":
            predicted = _dist_predict(eng, _ITERS, _S, tr_kw.get("sync"),
                                      degrade)
            dts, sizes = _dist_payload(eng)
        else:
            dts, sizes, n_wired = _lattice_payload(eng)
            predicted = _lattice_predict(_ITERS, n_wired, degrade)
            from repro.core.lattice_dsim import fused_working_set_bytes
            working_set = (
                fused_working_set_bytes(
                    eng.brick, lat.n_colors, precision=prec,
                    lanes=eng.replicas),
                tuple(eng.brick))
        if degrade:
            counters["seq"] = _STATE_LEAVES[engine]

        audits.append(ChunkAudit(
            engine=engine, precision=prec, variant=variant,
            closed=traced.jaxpr, predicted=predicted,
            payload_dtypes=dts, payload_bytes=sizes,
            counters=counters, working_set=working_set))
    return audits, failures


def trace_failures(failures) -> list:
    from .findings import Finding
    return [Finding(
        "IR-TRACE", loc,
        f"configuration failed to trace: {msg}",
        "trace_chunk over an AbstractMesh is part of the engine audit "
        "API — fix the hook or the engine") for loc, msg in failures]
