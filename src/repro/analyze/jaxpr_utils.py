"""Jaxpr walking primitives for the IR contract rules.

Everything here is dtype/shape bookkeeping over a ``ClosedJaxpr`` obtained
from ``trace_chunk`` — no device, no compile.  The recursion understands
the three nesting styles that actually occur in the engines' chunk
programs: call-like primitives whose param is a ``ClosedJaxpr`` (pjit,
scan, while, cond, remat), ``shard_map`` whose param is a *raw* ``Jaxpr``,
and list-valued params (cond branches).
"""

from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["unwrap", "iter_eqns", "collective_counts", "outvar_producer",
           "eqn_bytes", "aval_bytes", "COLLECTIVE_PRIMS",
           "FLOAT_ARITH_PRIMS", "CALLBACK_PRIMS", "first_float_arith",
           "callback_eqns", "collectives", "shard_body_cost"]

COLLECTIVE_PRIMS = frozenset({
    "all_gather", "ppermute", "psum", "pmax", "pmin", "all_to_all",
    "reduce_scatter", "psum_scatter", "pbroadcast", "axis_index"}
    - {"axis_index"})

# float arithmetic the int8/bitplane chunk bodies must not contain; data
# movement (gather/concat/select/transpose), conversions, bitcasts, and
# comparisons are allowed — they don't do float math, they move or
# reinterpret values
FLOAT_ARITH_PRIMS = frozenset({
    "add", "sub", "mul", "div", "rem", "neg", "max", "min", "abs", "sign",
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "erf", "erf_inv",
    "rsqrt", "sqrt", "cbrt", "pow", "integer_pow", "atan2", "sin", "cos",
    "tan", "dot_general", "reduce_sum", "reduce_max", "reduce_min",
    "reduce_prod", "cumsum", "cumprod", "cumlogsumexp", "add_any",
    "floor", "ceil", "round", "nextafter", "clamp",
})

CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "python_callback",
    "callback", "outside_call", "host_callback_call",
})

# shape-only ops the counter-producer resolution may look through
_PASSTHROUGH = frozenset({
    "reshape", "squeeze", "broadcast_in_dim", "transpose", "copy",
    "expand_dims", "rev",
})


def unwrap(j):
    """ClosedJaxpr | Jaxpr -> Jaxpr."""
    return j.jaxpr if hasattr(j, "jaxpr") and hasattr(j.jaxpr, "eqns") else j


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        for vv in (v if isinstance(v, (list, tuple)) else (v,)):
            if hasattr(vv, "eqns"):
                yield vv
            elif hasattr(vv, "jaxpr") and hasattr(vv.jaxpr, "eqns"):
                yield vv.jaxpr


def iter_eqns(jaxpr, mult: int = 1) -> Iterator[Tuple[object, int]]:
    """Yield ``(eqn, runtime_multiplier)`` over the whole nested program.

    The multiplier folds in enclosing scan lengths, so summing it per
    primitive gives the number of *executions* per chunk call — the
    quantity the sync_every staleness contract (IR-C) predicts.
    """
    for eq in unwrap(jaxpr).eqns:
        yield eq, mult
        m2 = mult
        if eq.primitive.name == "scan":
            m2 = mult * int(eq.params.get("length", 1))
        elif eq.primitive.name == "while":
            m2 = mult  # trip count is dynamic; treat as one (engines
            #            never put collectives inside while loops)
        for sub in _sub_jaxprs(eq):
            yield from iter_eqns(sub, m2)


def collectives(jaxpr):
    """[(eqn, mult)] for every collective in the program."""
    return [(eq, m) for eq, m in iter_eqns(jaxpr)
            if eq.primitive.name in COLLECTIVE_PRIMS]


def collective_counts(jaxpr) -> dict:
    """{primitive name: runtime executions per chunk call}."""
    out: dict = {}
    for eq, m in collectives(jaxpr):
        out[eq.primitive.name] = out.get(eq.primitive.name, 0) + m
    return out


def first_float_arith(jaxpr) -> Optional[tuple]:
    """First (eqn, mult) doing f32/f64 arithmetic, else None."""
    for eq, m in iter_eqns(jaxpr):
        if eq.primitive.name not in FLOAT_ARITH_PRIMS:
            continue
        avals = [v.aval for v in list(eq.invars) + list(eq.outvars)
                 if hasattr(v, "aval")]
        if any(np.issubdtype(a.dtype, np.floating) for a in avals
               if hasattr(a, "dtype")):
            return eq, m
    return None


def callback_eqns(jaxpr):
    return [(eq, m) for eq, m in iter_eqns(jaxpr)
            if eq.primitive.name in CALLBACK_PRIMS]


def aval_bytes(aval) -> int:
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize


def eqn_bytes(eqn) -> int:
    """Total operand + result bytes of one equation."""
    tot = 0
    for v in list(eqn.invars) + list(eqn.outvars):
        if hasattr(v, "aval") and hasattr(v.aval, "shape"):
            tot += aval_bytes(v.aval)
    return tot


def outvar_producer(jaxpr, index: int):
    """Resolve the primitive that produces output ``index`` of the program.

    Descends through call-like primitives (pjit/shard_map/remat: output j
    maps to inner outvar j of the sub-jaxpr; scan/while: carries map
    positionally) and looks through shape-only ops.  Returns
    ``(primitive_name, eqn | None)``; ``("<input>", None)`` if the output
    is a passed-through input, ``("<literal>", None)`` for constants.
    """
    j = unwrap(jaxpr)
    var = j.outvars[index]
    seen = 0
    while True:
        seen += 1
        if seen > 200:
            return "<cycle>", None
        if not hasattr(var, "count") and hasattr(var, "val"):
            return "<literal>", None
        if any(var is v for v in j.invars) \
                or any(var is v for v in getattr(j, "constvars", ())):
            return "<input>", None
        producer = None
        for eq in reversed(j.eqns):
            if any(var is v for v in eq.outvars):
                producer = eq
                break
        if producer is None:
            return "<unknown>", None
        name = producer.primitive.name
        pos = [i for i, v in enumerate(producer.outvars) if v is var][0]
        subs = list(_sub_jaxprs(producer))
        if name in ("pjit", "closed_call", "core_call", "remat", "remat2",
                    "custom_jvp_call", "custom_vjp_call", "shard_map",
                    "scan", "while"):
            if not subs:
                return name, producer
            # scan/while outputs are [carries..., ys...] in both the eqn
            # and the body jaxpr, so the same position works; call-like
            # primitives map outputs 1:1
            j = unwrap(subs[0])
            if pos >= len(j.outvars):
                return name, producer
            var = j.outvars[pos]
            continue
        if name in _PASSTHROUGH and producer.invars:
            var = producer.invars[0]
            if not hasattr(var, "aval"):
                return "<literal>", None
            continue
        return name, producer
