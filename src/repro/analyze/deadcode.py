"""AL-DEAD — tier-1 import-graph reachability over ``src/repro``.

Builds the static module import graph by parsing every file (never
importing it), roots the walk at everything ``tests/``, ``benchmarks/``,
``tools/`` and ``examples/`` import, and reports the modules nothing
reaches.  Importing ``repro.x.y`` also executes ``repro/__init__.py`` and
``repro/x/__init__.py``, so package ancestors (and whatever *they*
import) are implicit edges.

A module that is genuinely a CLI entry point (reached by ``python -m``,
not by import) gets a waiver with that rationale — the report is a
budget, not an obituary.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Set, Tuple

from .findings import Finding

__all__ = ["import_graph", "reachable", "dead_modules", "run"]

_ROOT_DIRS = ("tests", "benchmarks", "tools", "examples")


def _module_name(py: Path, src: Path) -> str:
    rel = py.relative_to(src).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports_of(py: Path, pkg: str) -> Set[str]:
    """Absolute repro.* module names this file imports (best effort)."""
    try:
        tree = ast.parse(py.read_text(), filename=str(py))
    except SyntaxError:
        return set()
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    out.add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg.split(".")
                # level=1 → current package, each extra level pops one
                base = base[:len(base) - (node.level - 1)]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            if not (mod == "repro" or mod.startswith("repro.")):
                continue
            out.add(mod)
            for a in node.names:
                out.add(f"{mod}.{a.name}")   # may be a submodule; filtered
    return out


def import_graph(root: Path) -> Tuple[Dict[str, Set[str]], Dict[str, Path]]:
    """(edges, module -> file) over every module in src/repro."""
    src = root / "src"
    files = {_module_name(p, src): p for p in sorted(src.rglob("*.py"))}
    edges: Dict[str, Set[str]] = {}
    for mod, py in files.items():
        pkg = mod if py.name == "__init__.py" else mod.rpartition(".")[0]
        deps = {d for d in _imports_of(py, pkg) if d in files}
        # importing a module executes every ancestor package __init__
        for d in list(deps) + [mod]:
            parts = d.split(".")
            for i in range(1, len(parts)):
                anc = ".".join(parts[:i])
                if anc in files and anc != mod:
                    deps.add(anc)
        deps.discard(mod)
        edges[mod] = deps
    return edges, files


# imports embedded in code snippets the tests exec in subprocesses
# (run_py("""...""")) are invisible to ast — a raw-text scan of the root
# files catches them
_IMPORT_RE = re.compile(r"(?:^|[\s(])(?:from|import)\s+(repro(?:\.\w+)*)",
                        re.MULTILINE)


def _roots(root: Path, known: Set[str]) -> Set[str]:
    out: Set[str] = set()
    for d in _ROOT_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            out |= {m for m in _imports_of(py, "") if m in known}
            out |= {m for m in _IMPORT_RE.findall(py.read_text())
                    if m in known}
    return out


def reachable(edges: Dict[str, Set[str]], roots: Set[str]) -> Set[str]:
    seen, stack = set(), list(roots)
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        stack.extend(edges.get(m, ()))
        # reaching a module pulls in its ancestor packages too
        parts = m.split(".")
        stack.extend(".".join(parts[:i]) for i in range(1, len(parts)))
    return seen & set(edges)


def dead_modules(root: Path) -> List[Tuple[str, Path]]:
    edges, files = import_graph(root)
    live = reachable(edges, _roots(root, set(files)))
    return [(m, files[m]) for m in sorted(files)
            if m not in live and files[m].name != "__init__.py"]


def run(root: Path) -> List[Finding]:
    return [Finding(
        "AL-DEAD", str(py.relative_to(root)),
        f"module `{mod}` is unreachable from tests/, benchmarks/, tools/ "
        "and examples/",
        "delete it, wire it into the tier-1 surface, or waive it with a "
        "rationale (e.g. 'CLI entry point, run via python -m')")
        for mod, py in dead_modules(root)]
