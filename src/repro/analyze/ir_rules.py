"""Layer 1 — IR contract rules over traced chunk programs.

Each rule takes a :class:`ChunkAudit` (one engine x precision x variant
configuration traced through ``trace_chunk``) and returns findings.  The
rule catalogue (also in DESIGN.md):

  IR-A  no f32/f64 arithmetic in int8/bitplane chunk bodies
  IR-B  wire dtype/payload: collectives carry only the declared payload
        dtype and byte count; bitplane chunks never put 8-bit or unpacked
        tensors on the wire; headers are uint32
  IR-C  collective executions per chunk == the sync_every prediction
  IR-D  no host callbacks inside jitted chunks
  IR-E  chunk-crossing flip/seq counters are uint32-modular, never i32
  IR-F  the fused_working_set_bytes VMEM model agrees with the traced
        buffer sizes within a declared tolerance
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .findings import Finding
from .jaxpr_utils import (aval_bytes, callback_eqns, collective_counts,
                          collectives, eqn_bytes, first_float_arith,
                          iter_eqns, outvar_producer, unwrap)

__all__ = ["ChunkAudit", "audit_chunk", "IR_RULES"]

# a chunk's working set must stay within this factor of the declared
# VMEM model (IR-F): the traced jaxpr carries intermediates and padding
# the per-site model folds into constants, so agreement is coarse — the
# rule exists to catch order-of-magnitude drift (a forgotten buffer, a
# silently materialized halo pool), not byte-exact accounting
WORKING_SET_TOLERANCE = 4.0


@dataclasses.dataclass
class ChunkAudit:
    """One traced configuration plus its declared contracts."""

    engine: str
    precision: str
    variant: str                      # "sync=4" | "degrade" | ...
    closed: object                    # ClosedJaxpr from trace_chunk
    predicted: Dict[str, int]         # collective -> runtime executions
    payload_dtypes: Tuple[np.dtype, ...]   # allowed payload operand dtypes
    payload_bytes: Tuple[int, ...]    # allowed device-local payload sizes
    counters: Dict[str, int]          # "flips"/"seq" -> flat outvar index
    working_set: Optional[Tuple[int, Tuple[int, int, int]]] = None
    # (model bytes, device brick) — lattice only

    @property
    def loc(self) -> str:
        return f"ir:{self.engine}/{self.precision}/{self.variant}"

    @property
    def integer_body(self) -> bool:
        return self.precision in ("int8", "bitplane")


def _classify(audit: ChunkAudit):
    """Split the chunk's collectives into (payload, header, reduction)."""
    payload, header, reduction = [], [], []
    for eq, m in collectives(audit.closed):
        name = eq.primitive.name
        if name in ("psum", "pmax", "pmin"):
            reduction.append((eq, m))
            continue
        aval = eq.invars[0].aval
        if tuple(aval.shape) == (2,) and aval.dtype == np.uint32:
            header.append((eq, m))
        else:
            payload.append((eq, m))
    return payload, header, reduction


def rule_a_no_float_in_integer_body(audit: ChunkAudit) -> List[Finding]:
    if not audit.integer_body:
        return []
    hit = first_float_arith(audit.closed)
    if hit is None:
        return []
    eq, _ = hit
    dts = [str(v.aval.dtype) for v in eq.invars if hasattr(v, "aval")]
    return [Finding(
        "IR-A", audit.loc,
        f"float arithmetic `{eq.primitive.name}` ({', '.join(dts)}) inside "
        f"the {audit.precision} chunk body",
        "keep the integer inner loop float-free: move the computation to "
        "LUT build time or gate it on the f32/cmft path")]


def rule_b_wire_format(audit: ChunkAudit) -> List[Finding]:
    out: List[Finding] = []
    payload, header, _ = _classify(audit)
    for eq, _ in payload:
        aval = eq.invars[0].aval
        if aval.dtype not in audit.payload_dtypes:
            allowed = "/".join(str(np.dtype(d)) for d in audit.payload_dtypes)
            out.append(Finding(
                "IR-B", audit.loc,
                f"`{eq.primitive.name}` puts {aval.dtype}{tuple(aval.shape)} "
                f"on the wire; this configuration declares {allowed}",
                "publish the declared wire format and convert AFTER the "
                "collective (see boundary_payload())"))
            continue
        got = aval_bytes(aval)
        if audit.payload_bytes and got not in audit.payload_bytes:
            out.append(Finding(
                "IR-B", audit.loc,
                f"`{eq.primitive.name}` ships {got} B/device but the "
                f"declared boundary payload is "
                f"{sorted(set(audit.payload_bytes))} B",
                "the collective operand must be exactly the declared "
                "boundary slice — no widened or duplicated tensors"))
    for eq, _ in header:
        if eq.invars[0].aval.dtype != np.uint32:
            out.append(Finding(
                "IR-B", audit.loc,
                f"integrity header via `{eq.primitive.name}` is not uint32",
                "headers are [seq, checksum] uint32 pairs"))
    if audit.precision == "bitplane":
        for eq, _ in collectives(audit.closed):
            aval = eq.invars[0].aval
            if aval.dtype.itemsize == 1:
                out.append(Finding(
                    "IR-B", audit.loc,
                    f"8-bit tensor ({aval.dtype}) on the wire in a bitplane "
                    f"chunk via `{eq.primitive.name}`",
                    "bitplane chunks ship packed uint32 word planes only"))
    return out


def rule_c_collective_count(audit: ChunkAudit) -> List[Finding]:
    got = collective_counts(audit.closed)
    if got == audit.predicted:
        return []
    return [Finding(
        "IR-C", audit.loc,
        f"collective executions per chunk {got} != sync_every prediction "
        f"{audit.predicted}",
        "an exchange was added/removed without updating the staleness "
        "schedule (or the prediction in analyze/configs.py)")]


def rule_d_no_callbacks(audit: ChunkAudit) -> List[Finding]:
    hits = callback_eqns(audit.closed)
    if not hits:
        return []
    names = sorted({eq.primitive.name for eq, _ in hits})
    return [Finding(
        "IR-D", audit.loc,
        f"host callback(s) {names} inside the jitted chunk",
        "chunks must be pure device programs; hoist host I/O to the "
        "recording driver")]


def rule_e_modular_counters(audit: ChunkAudit) -> List[Finding]:
    out: List[Finding] = []
    for name, idx in audit.counters.items():
        jx = unwrap(audit.closed)
        aval = jx.outvars[idx].aval
        if name == "seq":
            if aval.dtype != np.uint32:
                out.append(Finding(
                    "IR-E", audit.loc,
                    f"exchange counter `seq` (output {idx}) is "
                    f"{aval.dtype}, not uint32",
                    "sequence counters advance in uint32"))
            continue
        prim, eq = outvar_producer(audit.closed, idx)
        ok = False
        if prim == "bitcast_convert_type" and eq is not None:
            src = eq.invars[0].aval
            ok = (src.dtype == np.uint32 and aval.dtype == np.int32)
        elif aval.dtype == np.uint32:
            ok = True
        if not ok:
            out.append(Finding(
                "IR-E", audit.loc,
                f"counter `{name}` (output {idx}, {aval.dtype}) is "
                f"published by `{prim}` — not the uint32-modular "
                "accumulate + bitcast pattern",
                "accumulate flip deltas in uint32 and publish via "
                "core.pbit.flips_publish (int32 is only the storage view)"))
    return out


def rule_f_working_set(audit: ChunkAudit) -> List[Finding]:
    if audit.working_set is None:
        return []
    model, brick = audit.working_set
    # the device-local working set: every buffer entering the shard_map
    # body plus the widest intermediate the body materializes
    body = None
    for eq, _ in iter_eqns(audit.closed):
        if eq.primitive.name == "shard_map":
            body = unwrap(eq.params["jaxpr"])
            break
    if body is None:
        return [Finding(
            "IR-F", audit.loc,
            "no shard_map body found to measure the working set against",
            "fused chunks run device-local inside shard_map")]
    invar_bytes = sum(aval_bytes(v.aval) for v in body.invars)
    widest = max((eqn_bytes(eq) for eq, _ in iter_eqns(body)), default=0)
    actual = invar_bytes + widest
    ratio = actual / float(model) if model else float("inf")
    if 1.0 / WORKING_SET_TOLERANCE <= ratio <= WORKING_SET_TOLERANCE:
        return []
    return [Finding(
        "IR-F", audit.loc,
        f"traced working set {actual} B vs fused_working_set_bytes model "
        f"{model} B for brick {brick} (ratio {ratio:.2f}, tolerance "
        f"x{WORKING_SET_TOLERANCE})",
        "re-derive _per_site_bytes or find the buffer the model forgot")]


IR_RULES: Tuple[Callable[[ChunkAudit], List[Finding]], ...] = (
    rule_a_no_float_in_integer_body,
    rule_b_wire_format,
    rule_c_collective_count,
    rule_d_no_callbacks,
    rule_e_modular_counters,
    rule_f_working_set,
)


def audit_chunk(audit: ChunkAudit) -> List[Finding]:
    out: List[Finding] = []
    for rule in IR_RULES:
        out.extend(rule(audit))
    return out
