"""Orchestration for the contract auditor: sections, waivers, exit code.

``run_all`` is what ``tools/repro_analyze.py`` (and CI's ``analyze``
step) calls: IR audit + AST lint + dead-code report, filtered through
the committed waiver file, rendered as one report whose exit code gates.
"""

from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional

from .findings import Finding, Waivers, render_report

__all__ = ["run_ir", "run_lint", "run_deadcode", "run_all",
           "DEFAULT_WAIVER_FILE", "repo_root"]

DEFAULT_WAIVER_FILE = "tools/analyze_waivers.txt"


def repo_root() -> Path:
    """The repo checkout containing this source tree."""
    return Path(__file__).resolve().parents[3]


def run_ir() -> List[Finding]:
    """Layer 1: trace every engine x precision x variant and audit it."""
    from .configs import build_audits, trace_failures
    from .ir_rules import audit_chunk

    audits, failures = build_audits()
    out: List[Finding] = trace_failures(failures)
    for a in audits:
        out.extend(audit_chunk(a))
    return out


def run_lint(root: Optional[Path] = None) -> List[Finding]:
    """Layer 2: AST rules over src/."""
    from .lint import lint_tree
    return lint_tree(root or repo_root())


def run_deadcode(root: Optional[Path] = None) -> List[Finding]:
    from . import deadcode
    return deadcode.run(root or repo_root())


def run_all(root: Optional[Path] = None,
            sections: Optional[List[str]] = None,
            waiver_file: Optional[str] = None,
            json_path: Optional[str] = None):
    """(report text, exit code).  ``sections`` defaults to all three."""
    root = root or repo_root()
    wanted = sections or ["ir", "lint", "deadcode"]
    results: Dict[str, List[Finding]] = {}
    for name in wanted:
        if name == "ir":
            results["ir"] = run_ir()
        elif name == "lint":
            results["lint"] = run_lint(root)
        elif name == "deadcode":
            results["deadcode"] = run_deadcode(root)
        else:
            raise ValueError(f"unknown section {name!r}")
    waivers = Waivers.load(root / (waiver_file or DEFAULT_WAIVER_FILE))
    return render_report(results, waivers, json_path=json_path)
