"""CI gate for BENCH_* records: required keys present, numbers finite.

A benchmark that silently drops a key (or records NaN/inf/zero because a
path crashed and a default leaked through) looks exactly like a benchmark
that ran — this check turns schema regressions into a red CI step.

Covers BENCH_flip_rate.json (kernel/engine throughput record, the default)
and BENCH_serve_load.json (serving-layer load benchmark); the serve-load
schema is selected by the payload's ``"bench": "serve_load"`` tag or a
``serve_load`` filename.

  python tools/check_bench_schema.py [BENCH_flip_rate.json|BENCH_serve_load.json]
"""

from __future__ import annotations

import json
import math
import sys

REQUIRED_NUMBERS = [
    "lattice_per_phase_R1_flips_per_s",
    "lattice_fused_R1_flips_per_s",
    "lattice_fused_int8_R1_flips_per_s",
    "lattice_bitplane_R32_flips_per_s",
    "lattice_bitplane_R64_flips_per_s",
    "speedup_fused_R1_vs_seed_dispatch",
    "speedup_int8_vs_f32_fused_R1",
    "engine_speedup_int8_vs_f32_R1",
    "speedup_fused_replica_batch_vs_seed_dispatch",
    "speedup_bitplane_vs_int8_R8",
    "speedup_bitplane_vs_int8_R32_per_lane",
]
REQUIRED_KEYS = REQUIRED_NUMBERS + [
    "mode", "problem", "host", "all_paths_flips_per_s",
    "sweeps_per_s_spread", "kernel_int8_vs_f32",
    "per_lane_flips_per_s", "bitplane_halo_payload",
    # the aggregate R32-vs-R8 ratio is easy to misread as per-lane; the
    # record must carry its own disclaimer
    "speedup_bitplane_vs_int8_R8_note",
    # the word wire format on the mesh engine + the lane-packed ladder
    "dsim_dist_bitplane", "apt_icm_packed",
    # the multi-word fabric: per-lane rate across stacked word planes
    "bitplane_word_scaling",
    # degraded-mode mesh: stale_hold under 0/10/30% dropped exchanges
    "degraded_mesh",
]
# the degraded arms the record must carry, in drop-fraction order; the
# gates: every arm completes, effective_eta is finite-positive and
# monotone non-increasing in the drop fraction, and exchanges dropped on
# the wire are DETECTED (not silently ingested)
DEGRADED_ARMS = ("0.0", "0.1", "0.3")
SPREAD_FIELDS = ("best", "min", "median", "trimmed_median", "max", "reps")

# every BENCH record carries a telemetry block from the obs subsystem:
# the EtaMeter probe (measured η must be a real number, not a NaN from a
# side that never ran) and at least these non-empty latency histograms
TELEMETRY_HISTS = {
    "flip_rate": ("bench_chunk_seconds",),
    "serve_load": ("serve_queue_wait_seconds", "serve_pump_chunk_seconds"),
}
ETA_NUMBERS = ("measured_eta", "eta_threshold", "margin",
               "f_comm_hz", "f_pbit_hz", "t_exchange_s", "t_pbit_sweep_s")


def _check_telemetry(payload: dict, errors: list, which: str):
    tele = payload.get("telemetry")
    if not isinstance(tele, dict):
        errors.append(f"telemetry: expected a dict (obs snapshot + "
                      f"EtaMeter report), got {tele!r}")
        return
    eta = tele.get("eta")
    if not isinstance(eta, dict):
        errors.append(f"telemetry.eta: expected an EtaMeter report, "
                      f"got {eta!r}")
    else:
        for f in ETA_NUMBERS:
            _finite_positive(f"telemetry.eta.{f}", eta.get(f), errors)
        for f in ("chunks_recorded", "sweeps_recorded", "exchanges_timed"):
            v = eta.get(f)
            if not isinstance(v, int) or v <= 0:
                errors.append(f"telemetry.eta.{f}: expected a positive "
                              f"count, got {v!r} — a side of the η "
                              "measurement never ran")
    metrics = tele.get("metrics")
    if not isinstance(metrics, dict):
        errors.append(f"telemetry.metrics: expected a registry snapshot, "
                      f"got {metrics!r}")
        return
    for name in TELEMETRY_HISTS[which]:
        fam = metrics.get(name)
        if not isinstance(fam, dict) or fam.get("type") != "histogram":
            errors.append(f"telemetry.metrics[{name}]: expected a "
                          "histogram family in the snapshot")
            continue
        total = sum(s.get("count", 0) for s in fam.get("series", [])
                    if isinstance(s, dict))
        if not total:
            errors.append(f"telemetry.metrics[{name}]: latency histogram "
                          "is empty — instrumentation never observed")
    if which == "flip_rate":
        ov = tele.get("overhead")
        frac = ov.get("overhead_fraction") if isinstance(ov, dict) else None
        if not isinstance(frac, (int, float)) or isinstance(frac, bool) \
                or not math.isfinite(frac):
            errors.append("telemetry.overhead.overhead_fraction: expected "
                          f"a finite number, got {frac!r} — the chunk-"
                          "timer cost was never measured")


def _check_degraded_mesh(payload: dict, errors: list):
    deg = payload.get("degraded_mesh")
    if not isinstance(deg, dict):
        if "degraded_mesh" in payload:
            errors.append(f"degraded_mesh: expected a dict, got {deg!r}")
        return
    _finite_positive("degraded_mesh.measured_eta_clean",
                     deg.get("measured_eta_clean"), errors)
    _finite_positive("degraded_mesh.eta_threshold",
                     deg.get("eta_threshold"), errors)
    arms = deg.get("arms")
    if not isinstance(arms, dict):
        errors.append(f"degraded_mesh.arms: expected a dict, got {arms!r}")
        return
    prev_eta = None
    for frac in DEGRADED_ARMS:
        arm = arms.get(frac)
        if not isinstance(arm, dict):
            errors.append(f"degraded_mesh.arms[{frac}]: missing arm — the "
                          "degraded sweep did not cover this drop fraction")
            continue
        if arm.get("completed") is not True:
            errors.append(f"degraded_mesh.arms[{frac}]: the job did not "
                          "complete (stale_hold must finish at <= 30% "
                          "dropped exchanges)")
        eta = arm.get("effective_eta")
        _finite_positive(f"degraded_mesh.arms[{frac}].effective_eta", eta,
                         errors)
        df = arm.get("delivered_fraction")
        if not isinstance(df, (int, float)) or isinstance(df, bool) \
                or not math.isfinite(df) or not 0.0 <= df <= 1.0:
            errors.append(f"degraded_mesh.arms[{frac}].delivered_fraction: "
                          f"expected a number in [0, 1], got {df!r}")
        if isinstance(eta, (int, float)) and math.isfinite(eta):
            if prev_eta is not None and eta > prev_eta:
                errors.append(
                    f"degraded_mesh.arms[{frac}]: effective_eta {eta} rose "
                    f"above the previous arm's {prev_eta} — held exchanges "
                    "must not raise the effective comm frequency")
            prev_eta = eta
        det = arm.get("detections")
        if float(frac) > 0 and (not isinstance(det, int) or det < 1):
            errors.append(f"degraded_mesh.arms[{frac}]: dropped exchanges "
                          f"but detections={det!r} — the integrity layer "
                          "ingested corrupt boundaries silently")
        if float(frac) == 0 and det != 0:
            errors.append(f"degraded_mesh.arms[{frac}]: detections={det!r} "
                          "with zero injected faults (false positives)")


def _finite_positive(name, v, errors):
    if not isinstance(v, (int, float)) or isinstance(v, bool) \
            or not math.isfinite(v) or v <= 0:
        errors.append(f"{name}: expected finite positive number, got {v!r}")


def check(payload: dict) -> list:
    errors = []
    for k in REQUIRED_KEYS:
        if k not in payload:
            errors.append(f"missing key: {k}")
    for k in REQUIRED_NUMBERS:
        if k in payload:
            _finite_positive(k, payload[k], errors)
    for path, v in payload.get("all_paths_flips_per_s", {}).items():
        _finite_positive(f"all_paths_flips_per_s[{path}]", v, errors)
    for path, stats in payload.get("sweeps_per_s_spread", {}).items():
        if not isinstance(stats, dict):
            errors.append(f"sweeps_per_s_spread[{path}]: expected a "
                          f"spread dict, got {stats!r}")
            continue
        entry_errors = []
        for f in SPREAD_FIELDS:
            if f not in stats:
                entry_errors.append(
                    f"sweeps_per_s_spread[{path}] missing {f!r}")
            else:
                _finite_positive(f"sweeps_per_s_spread[{path}].{f}",
                                 stats[f], entry_errors)
        if not entry_errors and stats["min"] > stats["best"]:
            entry_errors.append(f"sweeps_per_s_spread[{path}]: min > best")
        errors.extend(entry_errors)
    for path, v in payload.get("per_lane_flips_per_s", {}).items():
        _finite_positive(f"per_lane_flips_per_s[{path}]", v, errors)
    halo = payload.get("bitplane_halo_payload")
    if isinstance(halo, dict):
        for f in ("bytes_per_face_site_int8_R32",
                  "bytes_per_face_site_bitplane_R32", "shrink"):
            _finite_positive(f"bitplane_halo_payload.{f}", halo.get(f),
                             errors)
    # the speedup is only meaningful against a recorded host fingerprint
    if "speedup_bitplane_vs_int8_R8" in payload and \
            not isinstance(payload.get("host"), dict):
        errors.append("speedup_bitplane_vs_int8_R8 recorded without a "
                      "host fingerprint")
    dist = payload.get("dsim_dist_bitplane")
    if isinstance(dist, dict):
        for f in ("boundary_bytes_per_site_bitplane_R32",
                  "boundary_bytes_per_site_int8_unpacked_R32",
                  "boundary_shrink", "speedup_bitplane_vs_int8_unpacked"):
            _finite_positive(f"dsim_dist_bitplane.{f}", dist.get(f), errors)
        for path, v in dist.get("lane_flips_per_s", {}).items():
            _finite_positive(f"dsim_dist_bitplane.lane_flips_per_s[{path}]",
                             v, errors)
        if dist.get("payload_dtype") != "uint32":
            errors.append("dsim_dist_bitplane.payload_dtype: expected "
                          f"'uint32', got {dist.get('payload_dtype')!r} — "
                          "the boundary all-gather must ship native words")
        if dist.get("pack_compute_bitplane") != "none":
            errors.append("dsim_dist_bitplane.pack_compute_bitplane: the "
                          "word path must ship boundaries with zero "
                          "pack/unpack compute")
    elif "dsim_dist_bitplane" in payload:
        errors.append(f"dsim_dist_bitplane: expected a dict, got {dist!r}")
    apt = payload.get("apt_icm_packed")
    if isinstance(apt, dict):
        for side in ("packed_sweeps_per_s", "unpacked_sweeps_per_s"):
            stats = apt.get(side)
            if not isinstance(stats, dict):
                errors.append(f"apt_icm_packed.{side}: expected a spread "
                              f"dict, got {stats!r}")
                continue
            for f in SPREAD_FIELDS:
                v = stats.get(f)
                if v is None:
                    errors.append(f"apt_icm_packed.{side} missing {f!r}")
                else:
                    _finite_positive(f"apt_icm_packed.{side}.{f}", v, errors)
        _finite_positive("apt_icm_packed.speedup_packed_vs_unpacked",
                         apt.get("speedup_packed_vs_unpacked"), errors)
        swap = apt.get("swap_move_cost")
        if not isinstance(swap, dict):
            errors.append(f"apt_icm_packed.swap_move_cost: expected a dict, "
                          f"got {swap!r}")
        else:
            for f in ("packed_s", "unpacked_s"):
                _finite_positive(f"apt_icm_packed.swap_move_cost.{f}",
                                 swap.get(f), errors)
    elif "apt_icm_packed" in payload:
        errors.append(f"apt_icm_packed: expected a dict, got {apt!r}")
    ws = payload.get("bitplane_word_scaling")
    if isinstance(ws, dict):
        for side in ("per_lane_flips_per_s", "lane_efficiency_vs_one_word"):
            entries = ws.get(side)
            if not isinstance(entries, dict) or not entries:
                errors.append(f"bitplane_word_scaling.{side}: expected a "
                              f"non-empty dict, got {entries!r}")
                continue
            for w, v in entries.items():
                _finite_positive(f"bitplane_word_scaling.{side}[{w}]", v,
                                 errors)
    elif "bitplane_word_scaling" in payload:
        errors.append(f"bitplane_word_scaling: expected a dict, got {ws!r}")
    k2k = payload.get("kernel_int8_vs_f32")
    if isinstance(k2k, dict):
        for side in ("f32_flips_per_s", "int8_flips_per_s"):
            stats = k2k.get(side)
            if not isinstance(stats, dict):
                errors.append(f"kernel_int8_vs_f32.{side}: expected a "
                              f"spread dict, got {stats!r}")
                continue
            for f in SPREAD_FIELDS:
                v = stats.get(f)
                if v is None:
                    errors.append(f"kernel_int8_vs_f32.{side} missing {f!r}")
                else:
                    _finite_positive(f"kernel_int8_vs_f32.{side}.{f}", v,
                                     errors)
        _finite_positive("kernel_int8_vs_f32.speedup_int8_vs_f32",
                         k2k.get("speedup_int8_vs_f32"), errors)
    _check_degraded_mesh(payload, errors)
    _check_telemetry(payload, errors, "flip_rate")
    return errors


SERVE_WAVE_NUMBERS = ("throughput_jobs_per_s", "p50_ms", "p95_ms", "p99_ms",
                      "flips_total", "elapsed_s")
SERVE_REQUIRED = ("bench", "mode", "host", "workload", "loads",
                  "speedup_packed_vs_baseline_best", "packing_observed",
                  "fault_waves")
# per fault wave: must be present and finite-positive
FAULT_WAVE_NUMBERS = ("goodput_jobs_per_s", "p99_ms", "elapsed_s")
# per fault wave: must be present and finite-nonnegative (all legitimately
# zero at the 0% injection rate)
FAULT_WAVE_COUNTS = ("injected_fault_rate", "jobs", "done", "failed",
                     "retries", "quarantined_batches", "bisect_requeues",
                     "faults_injected", "checkpoints_written",
                     "recovered_sweeps", "restarted_sweeps")


def _finite_nonneg(name, v, errors):
    if not isinstance(v, (int, float)) or isinstance(v, bool) \
            or not math.isfinite(v) or v < 0:
        errors.append(f"{name}: expected finite non-negative number, "
                      f"got {v!r}")


def _check_fault_waves(payload: dict, errors: list):
    waves = payload.get("fault_waves")
    if not isinstance(waves, list) or not waves:
        errors.append(f"fault_waves: expected a non-empty list, "
                      f"got {waves!r}")
        return
    for i, w in enumerate(waves):
        if not isinstance(w, dict):
            errors.append(f"fault_waves[{i}]: expected a dict, got {w!r}")
            continue
        for f in FAULT_WAVE_NUMBERS:
            _finite_positive(f"fault_waves[{i}].{f}", w.get(f), errors)
        for f in FAULT_WAVE_COUNTS:
            _finite_nonneg(f"fault_waves[{i}].{f}", w.get(f), errors)
        ph = w.get("phase_s")
        if not isinstance(ph, dict):
            errors.append(f"fault_waves[{i}].phase_s: expected a "
                          f"build/run/drain phase dict, got {ph!r}")
        else:
            for f in ("build", "run", "drain"):
                _finite_nonneg(f"fault_waves[{i}].phase_s.{f}",
                               ph.get(f), errors)
        done, failed, jobs = w.get("done"), w.get("failed"), w.get("jobs")
        if isinstance(done, int) and isinstance(failed, int) \
                and isinstance(jobs, int) and done + failed > jobs:
            errors.append(f"fault_waves[{i}]: done {done} + failed "
                          f"{failed} > jobs {jobs}")
        if w.get("injected_fault_rate") == 0 and w.get("done") != jobs:
            errors.append(f"fault_waves[{i}]: jobs failed at 0% injection "
                          "(the recovery machinery broke the happy path)")
    rates = [w.get("injected_fault_rate") for w in waves
             if isinstance(w, dict)]
    if 0 not in rates or not any(isinstance(r, float) and r > 0
                                 for r in rates):
        errors.append("fault_waves: need a 0% baseline wave and at least "
                      f"one nonzero injection rate, got rates {rates!r}")


def check_serve_load(payload: dict) -> list:
    """BENCH_serve_load.json: every load entry carries packed + baseline
    waves with finite latency percentiles and throughput, engine-call
    counts consistent with job counts, the packing evidence bit, and the
    fault waves (goodput under 0/5/20% injected chunk failures)."""
    errors = []
    for k in SERVE_REQUIRED:
        if k not in payload:
            errors.append(f"missing key: {k}")
    _finite_positive("speedup_packed_vs_baseline_best",
                     payload.get("speedup_packed_vs_baseline_best"), errors)
    loads = payload.get("loads")
    if not isinstance(loads, list) or not loads:
        errors.append(f"loads: expected a non-empty list, got {loads!r}")
        return errors
    for i, entry in enumerate(loads):
        if not isinstance(entry, dict):
            errors.append(f"loads[{i}]: expected a dict, got {entry!r}")
            continue
        _finite_positive(f"loads[{i}].speedup_packed_vs_baseline",
                         entry.get("speedup_packed_vs_baseline"), errors)
        for mode in ("packed", "baseline"):
            wave = entry.get(mode)
            if not isinstance(wave, dict):
                errors.append(f"loads[{i}].{mode}: expected a wave dict, "
                              f"got {wave!r}")
                continue
            for f in SERVE_WAVE_NUMBERS:
                _finite_positive(f"loads[{i}].{mode}.{f}", wave.get(f),
                                 errors)
            jobs, calls = wave.get("jobs"), wave.get("engine_calls")
            _finite_positive(f"loads[{i}].{mode}.jobs", jobs, errors)
            _finite_positive(f"loads[{i}].{mode}.engine_calls", calls,
                             errors)
            if isinstance(jobs, int) and isinstance(calls, int) \
                    and calls > jobs:
                errors.append(f"loads[{i}].{mode}: engine_calls {calls} > "
                              f"jobs {jobs}")
    if payload.get("packing_observed") is not True:
        errors.append("packing_observed: scheduler never batched "
                      "compatible jobs (expected engine_calls < jobs "
                      "under burst load)")
    _check_fault_waves(payload, errors)
    _check_telemetry(payload, errors, "serve_load")
    return errors


def main(argv) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_flip_rate.json"
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read {path}: {e}")
        return 1
    serve = payload.get("bench") == "serve_load" or "serve_load" in path
    errors = check_serve_load(payload) if serve else check(payload)
    if errors:
        print(f"FAIL: {path} schema regressions:")
        for e in errors:
            print(f"  - {e}")
        return 1
    which = "serve_load" if serve else "flip_rate"
    print(f"OK: {path} — {which} schema: required keys present, "
          "all numbers finite and positive")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
