"""Emit the EXPERIMENTS.md roofline/dry-run tables from reports/dryrun."""
import json, glob, sys

rows = [json.load(open(f)) for f in sorted(glob.glob("reports/dryrun/*.json"))]
single = [r for r in rows if r["mesh"] == "single_pod_16x16"]
multi = [r for r in rows if r["mesh"] != "single_pod_16x16"]

def fmt(x, nd=2):
    if x is None: return "—"
    return f"{x:.{nd}e}" if (x and (abs(x) >= 1e4 or abs(x) < 1e-3)) else f"{x:.{nd}f}"

print("### Single-pod (16x16 = 256 chips) baseline roofline, per chip per step\n")
print("| arch | shape | HLO FLOPs | HLO bytes | wire bytes | t_comp s | t_mem s | t_coll s | bottleneck | 6ND/HLO | grad_acc |")
print("|---|---|---|---|---|---|---|---|---|---|---|")
for r in single:
    rf = r["roofline"]
    u = rf.get("useful_ratio")
    ga = r.get("extras", {}).get("grad_accum", "")
    print(f"| {r['arch']} | {r['shape']} | {fmt(rf['flops'])} | {fmt(rf['bytes_accessed'])} "
          f"| {fmt(rf['wire_bytes'])} | {fmt(rf['t_compute'],3)} | {fmt(rf['t_memory'],3)} "
          f"| {fmt(rf['t_collective'],3)} | {rf['bottleneck']} | {fmt(u,3) if u else '—'} | {ga} |")

print("\n### Multi-pod (2x16x16 = 512 chips) dry-run: compile + collective check\n")
print("| arch | shape | compile s | wire bytes/chip | per-kind |")
print("|---|---|---|---|---|")
for r in multi:
    rf = r["roofline"]
    pk = ", ".join(f"{k.split('-')[-1]}={fmt(v)}" for k, v in sorted(rf["per_kind"].items()))
    print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f} | {fmt(rf['wire_bytes'])} | {pk or '—'} |")

print("\n### Memory fit (single-pod, per device)\n")
print("| arch | shape | args B | temp B | state B/dev | cache B/dev | fits 16GB |")
print("|---|---|---|---|---|---|---|")
for r in single:
    m = r["memory_analysis"]; ex = r.get("extras", {})
    arg = m.get("argument_size_in_bytes") or 0
    tmp = m.get("temp_size_in_bytes") or 0
    stt = ex.get("state_bytes_per_dev") or ex.get("param_bytes_per_dev") or 0
    cch = ex.get("cache_bytes_per_dev") or 0
    tot = (stt + cch + tmp)
    print(f"| {r['arch']} | {r['shape']} | {fmt(arg)} | {fmt(tmp)} | {fmt(stt)} | {fmt(cch) if cch else '—'} | "
          f"{'YES' if tot < 16e9 else 'NO (' + fmt(tot) + ')'} |")
