"""Contract auditor CLI — the repo's static-analysis gate.

  python tools/repro_analyze.py                 # everything (ir+lint+deadcode)
  python tools/repro_analyze.py ir              # jaxpr contract audit only
  python tools/repro_analyze.py lint            # AST rules over src/ only
  python tools/repro_analyze.py deadcode        # import-graph report only
  python tools/repro_analyze.py bench-schema F  # BENCH_*.json schema gate
  python tools/repro_analyze.py all --json out.json

Exit code 0 iff every finding is waived in tools/analyze_waivers.txt
(see DESIGN.md "Static analysis" for the rule catalogue and waiver
semantics).
"""

from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_analyze",
        description="IR contract audit + repo lint + dead-code gate")
    ap.add_argument("section", nargs="?", default="all",
                    choices=["all", "ir", "lint", "deadcode",
                             "bench-schema"],
                    help="which layer to run (default: all)")
    ap.add_argument("bench_file", nargs="?", default=None,
                    help="payload path for bench-schema")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="also write the findings as JSON")
    ap.add_argument("--waivers", default=None,
                    help="waiver file (default tools/analyze_waivers.txt)")
    args = ap.parse_args(argv)

    if args.section == "bench-schema":
        # the pre-existing BENCH_*.json gate, absorbed as a subcommand
        import check_bench_schema
        return check_bench_schema.main(
            ["check_bench_schema"]
            + ([args.bench_file] if args.bench_file else []))

    from repro.analyze.runner import run_all
    sections = None if args.section == "all" else [args.section]
    text, code = run_all(sections=sections, waiver_file=args.waivers,
                         json_path=args.json_path)
    print(text)
    return code


if __name__ == "__main__":
    sys.exit(main())
